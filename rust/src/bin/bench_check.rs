//! bench_check — the CI bench regression gate.
//!
//! Compares a fresh `BENCH_serve.json` (written by `cargo bench --bench
//! bench_serve`) against a committed `BENCH_baseline.json` and fails
//! (exit 1) when a gated metric regresses beyond the tolerance, or when
//! a required acceptance boolean is false. Writes a markdown delta
//! table to stdout and, when running under GitHub Actions, appends it
//! to `$GITHUB_STEP_SUMMARY`.
//!
//! Gated metrics are the *simulated-time* tail latencies (deterministic
//! given the seeds — they move only when the code moves, so a tight
//! relative gate is meaningful across runners). Wall-clock sections are
//! reported, not baselined: shared CI runners make absolute numbers
//! weather, not signal. The one wall-clock check enforced is *relative
//! within a single run* — steal-mode p99 must not exceed condvar-mode
//! p99 at 8 workers by more than a wide slack
//! ([`SCHED_8W_SLACK_PCT`]): both sides run on the same box seconds
//! apart so runner speed cancels, and the slack absorbs what OS jitter
//! remains while still catching a genuinely regressed steal path.
//!
//! A baseline value of `null` (or a missing key) means "seeded, not yet
//! measured": the fresh value is reported and passes. To (re)arm the
//! gate after an intentional perf change, copy the fresh file over the
//! baseline and commit it:
//!
//! ```sh
//! cargo bench --bench bench_serve
//! cp BENCH_serve.json BENCH_baseline.json   # then commit
//! ```

use anyhow::{bail, Result};

use celeste::jsonlite::{self, Value};

/// A gated metric: dotted path into the bench JSON, lower is better.
struct Gate {
    path: &'static str,
    label: &'static str,
}

const GATES: [Gate; 14] = [
    Gate { path: "dist.random_p99_ms", label: "dist hotspot p99 (random routing)" },
    Gate { path: "dist.rr_p99_ms", label: "dist hotspot p99 (round-robin)" },
    Gate { path: "dist.p2c_p99_ms", label: "dist hotspot p99 (p2c)" },
    Gate { path: "hedged.p2c_p999_ms", label: "p2c-alone p999" },
    Gate { path: "hedged.hedged_p999_ms", label: "hedged p999" },
    Gate { path: "ingest.quiesced_p99_ms", label: "drift read p99, quiesced" },
    Gate { path: "ingest.ingesting_p99_ms", label: "drift read p99, ingesting" },
    Gate { path: "ingest.fresh_p99_ms", label: "drift read p99, fresh consistency" },
    // Per-stage breakdown of the same simulated p2c run (schema v6):
    // gating each stage, not just the end-to-end tail, localizes a
    // regression to queueing, shard service, or the fabric residual.
    Gate { path: "stages.per_stage.queue_wait.p99_ms", label: "stage p99: queue wait (sim p2c)" },
    Gate {
        path: "stages.per_stage.shard_execute.p99_ms",
        label: "stage p99: shard execute (sim p2c)",
    },
    Gate { path: "stages.per_stage.net_rtt.p99_ms", label: "stage p99: net rtt (sim p2c)" },
    // Windowed-collector rollup of the same simulated p2c run (schema
    // v7): the median window pins steady-state p99, the worst window
    // catches a tail that only shows up in a bad stretch the full-run
    // aggregate would average away.
    Gate { path: "timeline.steady_p99_ms", label: "timeline steady-state p99 (median window)" },
    Gate { path: "timeline.worst_p99_ms", label: "timeline worst-window p99" },
    // Control-plane pass (schema v8): the rebalanced side of the
    // moving-hotspot run is simulated-time deterministic, so its tail
    // is gated like the other dist metrics.
    Gate { path: "control.rebalanced_p99_ms", label: "control moving-hotspot p99 (rebalanced)" },
];

/// Acceptance booleans that must be true in the fresh run.
const REQUIRED_TRUE: [(&str, &str); 5] = [
    ("dist.p2c_beats_random", "p2c beats random routing on hotspot p99"),
    ("failover.zero_failed", "zero failed queries through a replica kill"),
    ("transport.parity", "tcp transport byte-identical to in-process execution"),
    (
        "control.rebalance_beats_static_imbalance",
        "rebalancing beats static placement on load imbalance (moving hotspot)",
    ),
    (
        "control.rebalance_beats_static_p99",
        "rebalancing beats static placement on request p99 (moving hotspot)",
    ),
];

/// Reported (never gated) booleans — wall-clock, runner-dependent.
const INFORMATIONAL: [(&str, &str); 1] = [(
    "scheduler.steal_beats_condvar_p99_8w",
    "steal p99 <= condvar p99 at 8 workers (strict, wall clock)",
)];

/// Slack for the 8-worker steal-vs-condvar comparison, far wider than
/// the baseline tolerance: both runs execute on the same box seconds
/// apart, so runner *speed* cancels, but p99 under deliberate overload
/// still jitters with OS scheduling on shared runners. 100% (steal may
/// not be worse than 2x condvar) passes through that noise while still
/// failing a steal path whose tail has genuinely regressed.
const SCHED_8W_SLACK_PCT: f64 = 100.0;

/// The scheduler acceptance criterion: at 8 workers, steal-mode p99
/// must not exceed condvar-mode p99 by more than
/// [`SCHED_8W_SLACK_PCT`]. The strict `<=` comparison stays
/// informational (see [`INFORMATIONAL`]).
fn check_scheduler_8w(fresh: &Value, slack_pct: f64, md: &mut String, failures: &mut Vec<String>) {
    let row_8w = lookup(fresh, "scheduler.per_workers")
        .and_then(Value::as_arr)
        .and_then(|rows| {
            rows.iter().find(|r| r.get("workers").and_then(Value::as_f64) == Some(8.0))
        });
    let Some(row) = row_8w else {
        failures.push("scheduler.per_workers has no 8-worker row".to_string());
        md.push_str("| steal vs condvar p99, 8 workers | — | **missing** | — | ❌ |\n");
        return;
    };
    let cv = row.get("condvar_p99_ms").and_then(Value::as_f64);
    let st = row.get("steal_p99_ms").and_then(Value::as_f64);
    match (cv, st) {
        (Some(cv), Some(st)) if cv > 0.0 => {
            let delta_pct = (st - cv) / cv * 100.0;
            let status = if delta_pct > slack_pct {
                failures.push(format!(
                    "steal p99 at 8 workers is {delta_pct:.1}% above condvar \
                     ({st:.3} vs {cv:.3} ms, slack {slack_pct:.0}%)"
                ));
                "❌ regression"
            } else {
                "✅"
            };
            md.push_str(&format!(
                "| steal vs condvar p99, 8 workers | {cv:.3} ms | {st:.3} ms | {delta_pct:+.1}% | {status} |\n"
            ));
        }
        _ => {
            failures.push("scheduler 8-worker p99 values missing or non-numeric".to_string());
            md.push_str("| steal vs condvar p99, 8 workers | — | **missing** | — | ❌ |\n");
        }
    }
}

/// Per-frame codec budget, microseconds. Encode/decode cost is wall
/// clock, so it is not baselined; this absolute bound is deliberately
/// enormous (a millisecond to frame one request) — it passes any
/// runner weather and fails only a pathologically regressed codec
/// (accidental quadratic copy, per-field allocation storm).
const CODEC_BUDGET_US: f64 = 1000.0;

/// The transport section must cover every server count the bench
/// promises (1/4/8) with numeric sim/tcp tails and codec costs, and
/// the codec must fit [`CODEC_BUDGET_US`]. Tails themselves are
/// wall-clock and therefore reported, never gated.
fn check_transport(fresh: &Value, md: &mut String, failures: &mut Vec<String>) {
    let rows = lookup(fresh, "transport.per_servers").and_then(Value::as_arr);
    let Some(rows) = rows else {
        failures.push("transport.per_servers missing from the fresh bench output".to_string());
        md.push_str("| transport sim vs tcp | — | **missing** | — | ❌ |\n");
        return;
    };
    for want in [1.0, 4.0, 8.0] {
        let row = rows
            .iter()
            .find(|r| r.get("servers").and_then(Value::as_f64) == Some(want));
        let Some(row) = row else {
            failures.push(format!("transport.per_servers has no {want}-server row"));
            md.push_str(&format!(
                "| transport @ {want} server(s) | — | **missing** | — | ❌ |\n"
            ));
            continue;
        };
        let get = |k: &str| row.get(k).and_then(Value::as_f64);
        match (get("sim_p99_ms"), get("tcp_p99_ms"), get("encode_us_per_req"), get("decode_us_per_req")) {
            (Some(sim), Some(tcp), Some(enc), Some(dec)) => {
                let codec_ok = enc <= CODEC_BUDGET_US && dec <= CODEC_BUDGET_US;
                if !codec_ok {
                    failures.push(format!(
                        "transport codec cost at {want} server(s) blew the {CODEC_BUDGET_US:.0}us \
                         budget (encode {enc:.1}us, decode {dec:.1}us)"
                    ));
                }
                md.push_str(&format!(
                    "| transport p99 @ {want} server(s), sim vs tcp | {sim:.3} ms | {tcp:.3} ms | \
                     enc {enc:.1}us dec {dec:.1}us | {} |\n",
                    if codec_ok { "✅ (tails informational)" } else { "❌ codec budget" }
                ));
            }
            _ => {
                failures.push(format!(
                    "transport row at {want} server(s) is missing numeric tails or codec costs"
                ));
                md.push_str(&format!(
                    "| transport @ {want} server(s) | — | **incomplete** | — | ❌ |\n"
                ));
            }
        }
    }
}

/// Minimum collector windows the timeline section must close on the
/// simulated run — fewer means the collector barely ticked and the
/// steady/worst split is meaningless.
const TIMELINE_MIN_WINDOWS: f64 = 4.0;

/// Structural checks on the windowed-collector section: enough closed
/// windows, and zero gaps (nothing is killed in the simulated p2c run,
/// so any gap means the collector lost a sample it should have had).
fn check_timeline_section(fresh: &Value, md: &mut String, failures: &mut Vec<String>) {
    let windows = lookup(fresh, "timeline.windows").and_then(Value::as_f64);
    let gapped = lookup(fresh, "timeline.gapped").and_then(Value::as_f64);
    match (windows, gapped) {
        (Some(w), Some(g)) => {
            let ok = w >= TIMELINE_MIN_WINDOWS && g == 0.0;
            if !ok {
                failures.push(format!(
                    "timeline closed {w:.0} window(s) with {g:.0} gap(s); want at least \
                     {TIMELINE_MIN_WINDOWS:.0} windows and zero gaps on the simulated run"
                ));
            }
            md.push_str(&format!(
                "| timeline windows (gaps) | — | {w:.0} ({g:.0} gapped) | — | {} |\n",
                if ok { "✅" } else { "❌" }
            ));
        }
        _ => {
            failures.push("timeline.windows / timeline.gapped missing".to_string());
            md.push_str("| timeline windows (gaps) | — | **missing** | — | ❌ |\n");
        }
    }
}

/// Structural checks on the control-plane section: the controller must
/// have actually migrated at least one replica range, logged its
/// decisions, and failed zero queries while doing so (in-flight
/// queries keep succeeding during migration).
fn check_control_section(fresh: &Value, md: &mut String, failures: &mut Vec<String>) {
    let migrations = lookup(fresh, "control.migrations").and_then(Value::as_f64);
    let decisions = lookup(fresh, "control.decisions").and_then(Value::as_f64);
    let failed = lookup(fresh, "control.failed_queries").and_then(Value::as_f64);
    match (migrations, decisions, failed) {
        (Some(m), Some(d), Some(f)) => {
            let ok = m >= 1.0 && d >= 1.0 && f == 0.0;
            if !ok {
                failures.push(format!(
                    "control section shows {m:.0} migration(s), {d:.0} decision(s), \
                     {f:.0} failed quer(ies); want >= 1 migration, >= 1 decision, 0 failed"
                ));
            }
            md.push_str(&format!(
                "| control migrations (decisions, failed) | — | {m:.0} ({d:.0}, {f:.0}) | — | {} |\n",
                if ok { "✅" } else { "❌" }
            ));
        }
        _ => {
            failures.push(
                "control.migrations / control.decisions / control.failed_queries missing"
                    .to_string(),
            );
            md.push_str("| control migrations (decisions, failed) | — | **missing** | — | ❌ |\n");
        }
    }
}

fn lookup<'a>(root: &'a Value, path: &str) -> Option<&'a Value> {
    let mut cur = root;
    for part in path.split('.') {
        cur = cur.get(part)?;
    }
    Some(cur)
}

fn load(path: &str) -> Result<Value> {
    let text = match std::fs::read_to_string(path) {
        Ok(t) => t,
        Err(e) => bail!("cannot read {path}: {e}"),
    };
    match jsonlite::parse(&text) {
        Ok(v) => Ok(v),
        Err(e) => bail!("cannot parse {path}: {e}"),
    }
}

/// Title line of the markdown summary; the unarmed-gate warning is
/// inserted immediately after it so it leads the rendered report.
const MD_TITLE: &str = "## Bench regression gate\n\n";

fn main() -> Result<()> {
    let mut fresh_path = "BENCH_serve.json".to_string();
    let mut baseline_path = "BENCH_baseline.json".to_string();
    let mut max_regress_pct = 25.0f64;
    let mut args = std::env::args().skip(1);
    while let Some(a) = args.next() {
        let mut take = |name: &str| match args.next() {
            Some(v) => Ok(v),
            None => bail!("{name} needs a value"),
        };
        match a.as_str() {
            "--fresh" => fresh_path = take("--fresh")?,
            "--baseline" => baseline_path = take("--baseline")?,
            "--max-regress-pct" => {
                let v = take("--max-regress-pct")?;
                max_regress_pct = match v.parse() {
                    Ok(p) => p,
                    Err(_) => bail!("bad --max-regress-pct {v:?}"),
                };
            }
            other => bail!("unknown argument {other:?} (want --fresh/--baseline/--max-regress-pct)"),
        }
    }

    let fresh = load(&fresh_path)?;
    let baseline = load(&baseline_path)?;

    let mut md = String::new();
    md.push_str(MD_TITLE);
    md.push_str(&format!(
        "`{fresh_path}` vs committed `{baseline_path}` (tolerance {max_regress_pct:.0}%, \
         simulated-time metrics only)\n\n"
    ));
    md.push_str("| metric | baseline | fresh | delta | status |\n");
    md.push_str("|---|---:|---:|---:|---|\n");

    let mut failures: Vec<String> = Vec::new();
    let mut seeded = 0usize;
    for g in &GATES {
        let fresh_v = lookup(&fresh, g.path).and_then(Value::as_f64);
        let base_v = lookup(&baseline, g.path).and_then(Value::as_f64);
        match (fresh_v, base_v) {
            (None, _) => {
                failures.push(format!("`{}` missing from the fresh bench output", g.path));
                md.push_str(&format!("| {} | — | **missing** | — | ❌ |\n", g.label));
            }
            (Some(f), Some(b)) if b > 0.0 => {
                let delta_pct = (f - b) / b * 100.0;
                let status = if delta_pct > max_regress_pct {
                    failures.push(format!(
                        "`{}` regressed {:.1}% ({:.3} -> {:.3} ms, tolerance {:.0}%)",
                        g.path, delta_pct, b, f, max_regress_pct
                    ));
                    "❌ regression"
                } else if delta_pct < -max_regress_pct {
                    "✅ improved (consider refreshing the baseline)"
                } else {
                    "✅"
                };
                md.push_str(&format!(
                    "| {} | {:.3} ms | {:.3} ms | {:+.1}% | {} |\n",
                    g.label, b, f, delta_pct, status
                ));
            }
            (Some(f), _) => {
                seeded += 1;
                md.push_str(&format!(
                    "| {} | _seeded_ | {:.3} ms | — | ✅ (no baseline yet) |\n",
                    g.label, f
                ));
            }
        }
    }
    for (path, label) in &REQUIRED_TRUE {
        match lookup(&fresh, path).and_then(Value::as_bool) {
            Some(true) => md.push_str(&format!("| {label} | — | true | — | ✅ |\n")),
            got => {
                failures.push(format!("required acceptance `{path}` is {got:?}, want true"));
                md.push_str(&format!("| {label} | — | **{got:?}** | — | ❌ |\n"));
            }
        }
    }
    check_scheduler_8w(&fresh, SCHED_8W_SLACK_PCT, &mut md, &mut failures);
    check_transport(&fresh, &mut md, &mut failures);
    check_timeline_section(&fresh, &mut md, &mut failures);
    check_control_section(&fresh, &mut md, &mut failures);
    for (path, label) in &INFORMATIONAL {
        let got = lookup(&fresh, path).and_then(Value::as_bool);
        md.push_str(&format!(
            "| {label} | — | {} | — | ℹ️ informational |\n",
            match got {
                Some(b) => b.to_string(),
                None => "missing".to_string(),
            }
        ));
    }
    if seeded > 0 {
        // an unarmed gate is easy to mistake for a passing one: lead
        // the job summary with the warning, not a footnote, and echo
        // it to stderr so it shows in the raw log too
        let warning = format!(
            "> ⚠️ **UNARMED GATE:** `{baseline_path}` is still null-seeded for {seeded} of \
             {} gated metric(s) — a regression in any of them passes silently. Arm the gate \
             by committing a measured baseline:\n> `cargo bench --bench bench_serve && cp \
             BENCH_serve.json BENCH_baseline.json`\n\n",
            GATES.len()
        );
        md.insert_str(MD_TITLE.len(), &warning);
        eprintln!(
            "bench_check WARNING: {seeded} gated metric(s) have no committed baseline \
             (null-seeded {baseline_path}); the regression gate is NOT armed for them"
        );
    }
    md.push('\n');

    print!("{md}");
    if let Ok(summary) = std::env::var("GITHUB_STEP_SUMMARY") {
        use std::io::Write;
        let file = std::fs::OpenOptions::new().append(true).create(true).open(&summary);
        if let Ok(mut f) = file {
            let _ = f.write_all(md.as_bytes());
        }
    }

    if failures.is_empty() {
        println!("bench_check: OK");
        Ok(())
    } else {
        for f in &failures {
            eprintln!("bench_check FAIL: {f}");
        }
        bail!("{} bench gate failure(s)", failures.len());
    }
}
