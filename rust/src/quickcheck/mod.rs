//! quickcheck-lite: property-based testing (the offline registry has no
//! proptest). Deterministic generator streams + linear shrinking.
//!
//! ```ignore
//! quickcheck::forall(200, seed, gen, |case| property(case))
//! ```
//! On failure the input is shrunk (halving toward a trivial case) and the
//! minimal failing case reported in the panic message.

use crate::prng::Rng;

/// A generator of test cases plus a shrinker.
pub trait Arbitrary: Sized + std::fmt::Debug + Clone {
    fn generate(rng: &mut Rng) -> Self;
    /// Candidate smaller versions of `self` (simplest first).
    fn shrink(&self) -> Vec<Self> {
        Vec::new()
    }
}

impl Arbitrary for f64 {
    fn generate(rng: &mut Rng) -> Self {
        // mixture of scales, including negatives and near-zero
        match rng.below(4) {
            0 => rng.normal(),
            1 => rng.normal() * 1e3,
            2 => rng.normal() * 1e-3,
            _ => rng.uniform_in(-10.0, 10.0),
        }
    }
    fn shrink(&self) -> Vec<Self> {
        let mut out = Vec::new();
        if *self != 0.0 {
            out.push(0.0);
            out.push(self / 2.0);
        }
        out
    }
}

impl Arbitrary for usize {
    fn generate(rng: &mut Rng) -> Self {
        match rng.below(3) {
            0 => rng.below(8) as usize,
            1 => rng.below(256) as usize,
            _ => rng.below(65536) as usize,
        }
    }
    fn shrink(&self) -> Vec<Self> {
        let mut out = Vec::new();
        if *self > 0 {
            out.push(0);
            out.push(self / 2);
            if *self > 1 {
                out.push(self - 1);
            }
        }
        out
    }
}

impl<T: Arbitrary> Arbitrary for Vec<T> {
    fn generate(rng: &mut Rng) -> Self {
        let n = rng.below(32) as usize;
        (0..n).map(|_| T::generate(rng)).collect()
    }
    fn shrink(&self) -> Vec<Self> {
        let mut out = Vec::new();
        if !self.is_empty() {
            out.push(Vec::new());
            out.push(self[..self.len() / 2].to_vec());
            let mut tail = self.clone();
            tail.remove(0);
            out.push(tail);
        }
        out
    }
}

impl<A: Arbitrary, B: Arbitrary> Arbitrary for (A, B) {
    fn generate(rng: &mut Rng) -> Self {
        (A::generate(rng), B::generate(rng))
    }
    fn shrink(&self) -> Vec<Self> {
        let mut out: Vec<Self> = self.0.shrink().into_iter().map(|a| (a, self.1.clone())).collect();
        out.extend(self.1.shrink().into_iter().map(|b| (self.0.clone(), b)));
        out
    }
}

/// Check `prop` over `n` generated cases; panics with the minimal
/// (shrunk) counterexample on failure.
pub fn forall<T: Arbitrary, P: Fn(&T) -> bool>(n: usize, seed: u64, prop: P) {
    let mut rng = Rng::new(seed);
    for i in 0..n {
        let case = T::generate(&mut rng);
        if !prop(&case) {
            let minimal = shrink_loop(case, &prop);
            panic!("property failed on case {i}; minimal counterexample: {minimal:?}");
        }
    }
}

/// Like [`forall`] but with an explicit generator function.
pub fn forall_with<T: std::fmt::Debug, G, P>(n: usize, seed: u64, gen: G, prop: P)
where
    G: Fn(&mut Rng) -> T,
    P: Fn(&T) -> bool,
{
    let mut rng = Rng::new(seed);
    for i in 0..n {
        let case = gen(&mut rng);
        assert!(prop(&case), "property failed on case {i}: {case:?}");
    }
}

fn shrink_loop<T: Arbitrary, P: Fn(&T) -> bool>(mut failing: T, prop: &P) -> T {
    for _ in 0..64 {
        let mut advanced = false;
        for cand in failing.shrink() {
            if !prop(&cand) {
                failing = cand;
                advanced = true;
                break;
            }
        }
        if !advanced {
            break;
        }
    }
    failing
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passes_true_property() {
        forall::<Vec<usize>, _>(100, 1, |v| v.len() < 100_000);
    }

    #[test]
    #[should_panic(expected = "minimal counterexample")]
    fn fails_and_shrinks() {
        forall::<Vec<usize>, _>(500, 2, |v| v.iter().sum::<usize>() < 10);
    }

    #[test]
    fn shrinking_reaches_small_case() {
        // property: all vecs have < 3 elements — find and shrink
        let mut failing: Option<Vec<usize>> = None;
        let mut rng = Rng::new(3);
        for _ in 0..200 {
            let v = Vec::<usize>::generate(&mut rng);
            if v.len() >= 3 {
                failing = Some(v);
                break;
            }
        }
        let f = failing.expect("generator should produce a long vec");
        let minimal = shrink_loop(f, &|v: &Vec<usize>| v.len() < 3);
        assert!(minimal.len() >= 3 && minimal.len() <= 4, "{minimal:?}");
    }
}
