//! Runtime accounting: the six components the paper partitions measured
//! runtime into (§VI): "(a) garbage collection time, (b) image load time,
//! (c) load imbalance, (d) the time taken in retrieving elements of the
//! global arrays used, (e) dynamic scheduling overhead, and (f) source
//! optimization time."

use std::fmt;

#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Component {
    Gc,
    ImageLoad,
    LoadImbalance,
    GaFetch,
    Scheduling,
    Optimize,
}

pub const COMPONENTS: [Component; 6] = [
    Component::Gc,
    Component::ImageLoad,
    Component::LoadImbalance,
    Component::GaFetch,
    Component::Scheduling,
    Component::Optimize,
];

impl Component {
    pub fn name(&self) -> &'static str {
        match self {
            Component::Gc => "gc",
            Component::ImageLoad => "image_load",
            Component::LoadImbalance => "load_imbalance",
            Component::GaFetch => "ga_fetch",
            Component::Scheduling => "scheduling",
            Component::Optimize => "optimize",
        }
    }

    fn index(&self) -> usize {
        COMPONENTS.iter().position(|c| c == self).unwrap()
    }
}

/// Seconds attributed to each component (simulated or wall time).
#[derive(Clone, Debug, Default, PartialEq)]
pub struct Breakdown {
    secs: [f64; 6],
}

impl Breakdown {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn add(&mut self, c: Component, secs: f64) {
        debug_assert!(secs >= -1e-9, "negative time for {c:?}: {secs}");
        self.secs[c.index()] += secs.max(0.0);
    }

    pub fn get(&self, c: Component) -> f64 {
        self.secs[c.index()]
    }

    pub fn total(&self) -> f64 {
        self.secs.iter().sum()
    }

    pub fn merge(&mut self, other: &Breakdown) {
        for i in 0..6 {
            self.secs[i] += other.secs[i];
        }
    }

    /// Scale all components (e.g. to average across nodes).
    pub fn scaled(&self, k: f64) -> Breakdown {
        let mut out = self.clone();
        for s in &mut out.secs {
            *s *= k;
        }
        out
    }

    /// Component share of the total, in [0, 1].
    pub fn fraction(&self, c: Component) -> f64 {
        let t = self.total();
        if t <= 0.0 {
            0.0
        } else {
            self.get(c) / t
        }
    }

    /// Render the paper-style stacked table row.
    pub fn table_row(&self) -> String {
        COMPONENTS
            .iter()
            .map(|c| format!("{}={:.1}s ({:.1}%)", c.name(), self.get(*c), 100.0 * self.fraction(*c)))
            .collect::<Vec<_>>()
            .join("  ")
    }
}

impl fmt::Display for Breakdown {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.table_row())
    }
}

/// Wall-clock stopwatch for the real (non-simulated) execution paths.
pub struct Stopwatch {
    start: std::time::Instant,
}

impl Stopwatch {
    pub fn start() -> Self {
        Stopwatch { start: std::time::Instant::now() }
    }

    pub fn elapsed_secs(&self) -> f64 {
        self.start.elapsed().as_secs_f64()
    }
}

/// Simple streaming statistics (for task-time distributions etc.).
#[derive(Clone, Debug, Default)]
pub struct Stats {
    pub n: u64,
    pub sum: f64,
    pub sum2: f64,
    pub min: f64,
    pub max: f64,
}

impl Stats {
    pub fn new() -> Stats {
        Stats { n: 0, sum: 0.0, sum2: 0.0, min: f64::INFINITY, max: f64::NEG_INFINITY }
    }

    pub fn push(&mut self, x: f64) {
        self.n += 1;
        self.sum += x;
        self.sum2 += x * x;
        self.min = self.min.min(x);
        self.max = self.max.max(x);
    }

    pub fn mean(&self) -> f64 {
        if self.n == 0 {
            0.0
        } else {
            self.sum / self.n as f64
        }
    }

    pub fn var(&self) -> f64 {
        if self.n < 2 {
            return 0.0;
        }
        (self.sum2 / self.n as f64 - self.mean().powi(2)).max(0.0)
    }

    pub fn sd(&self) -> f64 {
        self.var().sqrt()
    }

    pub fn merge(&mut self, o: &Stats) {
        self.n += o.n;
        self.sum += o.sum;
        self.sum2 += o.sum2;
        self.min = self.min.min(o.min);
        self.max = self.max.max(o.max);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn breakdown_accumulates() {
        let mut b = Breakdown::new();
        b.add(Component::Gc, 2.0);
        b.add(Component::Gc, 1.0);
        b.add(Component::Optimize, 7.0);
        assert_eq!(b.get(Component::Gc), 3.0);
        assert_eq!(b.total(), 10.0);
        assert!((b.fraction(Component::Gc) - 0.3).abs() < 1e-12);
    }

    #[test]
    fn merge_and_scale() {
        let mut a = Breakdown::new();
        a.add(Component::GaFetch, 4.0);
        let mut b = Breakdown::new();
        b.add(Component::GaFetch, 2.0);
        b.add(Component::Scheduling, 1.0);
        a.merge(&b);
        assert_eq!(a.get(Component::GaFetch), 6.0);
        let half = a.scaled(0.5);
        assert_eq!(half.get(Component::GaFetch), 3.0);
        assert_eq!(half.get(Component::Scheduling), 0.5);
    }

    #[test]
    fn stats_moments() {
        let mut s = Stats::new();
        for x in [1.0, 2.0, 3.0, 4.0] {
            s.push(x);
        }
        assert_eq!(s.n, 4);
        assert!((s.mean() - 2.5).abs() < 1e-12);
        assert!((s.var() - 1.25).abs() < 1e-12);
        assert_eq!(s.min, 1.0);
        assert_eq!(s.max, 4.0);
    }

    #[test]
    fn empty_breakdown_fraction_zero() {
        let b = Breakdown::new();
        assert_eq!(b.fraction(Component::Gc), 0.0);
    }
}
