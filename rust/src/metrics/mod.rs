//! Runtime accounting: the six components the paper partitions measured
//! runtime into (§VI): "(a) garbage collection time, (b) image load time,
//! (c) load imbalance, (d) the time taken in retrieving elements of the
//! global arrays used, (e) dynamic scheduling overhead, and (f) source
//! optimization time."

use std::fmt;

#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Component {
    Gc,
    ImageLoad,
    LoadImbalance,
    GaFetch,
    Scheduling,
    Optimize,
}

pub const COMPONENTS: [Component; 6] = [
    Component::Gc,
    Component::ImageLoad,
    Component::LoadImbalance,
    Component::GaFetch,
    Component::Scheduling,
    Component::Optimize,
];

impl Component {
    pub fn name(&self) -> &'static str {
        match self {
            Component::Gc => "gc",
            Component::ImageLoad => "image_load",
            Component::LoadImbalance => "load_imbalance",
            Component::GaFetch => "ga_fetch",
            Component::Scheduling => "scheduling",
            Component::Optimize => "optimize",
        }
    }

    fn index(&self) -> usize {
        COMPONENTS.iter().position(|c| c == self).unwrap()
    }
}

/// Seconds attributed to each component (simulated or wall time).
#[derive(Clone, Debug, Default, PartialEq)]
pub struct Breakdown {
    secs: [f64; 6],
}

impl Breakdown {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn add(&mut self, c: Component, secs: f64) {
        debug_assert!(secs >= -1e-9, "negative time for {c:?}: {secs}");
        self.secs[c.index()] += secs.max(0.0);
    }

    pub fn get(&self, c: Component) -> f64 {
        self.secs[c.index()]
    }

    pub fn total(&self) -> f64 {
        self.secs.iter().sum()
    }

    pub fn merge(&mut self, other: &Breakdown) {
        for i in 0..6 {
            self.secs[i] += other.secs[i];
        }
    }

    /// Scale all components (e.g. to average across nodes).
    pub fn scaled(&self, k: f64) -> Breakdown {
        let mut out = self.clone();
        for s in &mut out.secs {
            *s *= k;
        }
        out
    }

    /// Component share of the total, in [0, 1].
    pub fn fraction(&self, c: Component) -> f64 {
        let t = self.total();
        if t <= 0.0 {
            0.0
        } else {
            self.get(c) / t
        }
    }

    /// Render the paper-style stacked table row.
    pub fn table_row(&self) -> String {
        COMPONENTS
            .iter()
            .map(|c| format!("{}={:.1}s ({:.1}%)", c.name(), self.get(*c), 100.0 * self.fraction(*c)))
            .collect::<Vec<_>>()
            .join("  ")
    }
}

impl fmt::Display for Breakdown {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.table_row())
    }
}

/// Evenly-strided subsample of `take` elements (reservoirs are
/// unordered, so a stride is an unbiased subsample; `take >= len`
/// returns everything).
fn subsample(src: &[f64], take: usize) -> Vec<f64> {
    if take == 0 || src.is_empty() {
        return Vec::new();
    }
    if take >= src.len() {
        return src.to_vec();
    }
    (0..take)
        .map(|i| src[i * (src.len() - 1) / (take - 1).max(1)])
        .collect()
}

/// Wall-clock stopwatch for the real (non-simulated) execution paths.
pub struct Stopwatch {
    start: std::time::Instant,
}

impl Stopwatch {
    pub fn start() -> Self {
        Stopwatch { start: std::time::Instant::now() }
    }

    pub fn elapsed_secs(&self) -> f64 {
        self.start.elapsed().as_secs_f64()
    }
}

/// Max retained samples per `Stats`; beyond this, quantiles become
/// reservoir/stride approximations with bounded (512 KiB) memory.
const SAMPLE_CAP: usize = 1 << 16;

/// Streaming statistics (for task-time distributions etc.) with
/// quantiles: moments are streamed; up to [`SAMPLE_CAP`] samples are
/// retained (exact quantiles below the cap, uniform reservoir above
/// it) and sorted at query time — use [`Stats::quantiles`] to sort
/// once for several quantiles. Used for the serve layer's latency
/// reporting and the cluster simulator's per-task latency
/// distribution.
#[derive(Clone, Debug, PartialEq)]
pub struct Stats {
    pub n: u64,
    pub sum: f64,
    pub sum2: f64,
    pub min: f64,
    pub max: f64,
    samples: Vec<f64>,
    /// xorshift state for reservoir replacement past the cap
    rng_state: u64,
}

// Default must agree with `new()` (INF/NEG_INF sentinels), otherwise a
// defaulted Stats merged into a real one corrupts min/max.
impl Default for Stats {
    fn default() -> Stats {
        Stats::new()
    }
}

impl Stats {
    pub fn new() -> Stats {
        Stats {
            n: 0,
            sum: 0.0,
            sum2: 0.0,
            min: f64::INFINITY,
            max: f64::NEG_INFINITY,
            samples: Vec::new(),
            rng_state: 0x9E3779B97F4A7C15,
        }
    }

    pub fn push(&mut self, x: f64) {
        self.n += 1;
        self.sum += x;
        self.sum2 += x * x;
        self.min = self.min.min(x);
        self.max = self.max.max(x);
        if self.samples.len() < SAMPLE_CAP {
            self.samples.push(x);
        } else {
            // algorithm R: keep a uniform sample of the full stream
            self.rng_state ^= self.rng_state << 13;
            self.rng_state ^= self.rng_state >> 7;
            self.rng_state ^= self.rng_state << 17;
            let j = (self.rng_state % self.n) as usize;
            if j < SAMPLE_CAP {
                self.samples[j] = x;
            }
        }
    }

    pub fn mean(&self) -> f64 {
        if self.n == 0 {
            0.0
        } else {
            self.sum / self.n as f64
        }
    }

    pub fn var(&self) -> f64 {
        if self.n < 2 {
            return 0.0;
        }
        (self.sum2 / self.n as f64 - self.mean().powi(2)).max(0.0)
    }

    pub fn sd(&self) -> f64 {
        self.var().sqrt()
    }

    /// Merge any number of distributions into one (the shared
    /// "all-classes" and per-worker fold used by every serving report).
    ///
    /// Deterministic by construction: samples are sorted before any
    /// thinning, so the result depends only on each part's retained
    /// sample multiset and stream length — not on the order the parts
    /// are folded in. While the union fits [`SAMPLE_CAP`] (the usual
    /// per-worker case) the merged quantiles are exact and also
    /// independent of how samples were partitioned across parts (e.g.
    /// which server worker happened to execute which request). Past the
    /// cap, each part contributes evenly-strided order statistics in
    /// proportion to its *stream* length — the same weighting pairwise
    /// [`Stats::merge`] applies, so a capped million-sample stream is
    /// not outvoted by an exact thousand-sample one. `merge` remains
    /// the cheap streaming fold; use this one wherever reproducible
    /// quantiles matter.
    pub fn merge_all<'a, I>(parts: I) -> Stats
    where
        I: IntoIterator<Item = &'a Stats>,
    {
        let mut all = Stats::new();
        let mut part_samples: Vec<(u64, &[f64])> = Vec::new();
        for s in parts {
            all.n += s.n;
            all.sum += s.sum;
            all.sum2 += s.sum2;
            all.min = all.min.min(s.min);
            all.max = all.max.max(s.max);
            part_samples.push((s.n, s.samples.as_slice()));
        }
        let retained: usize = part_samples.iter().map(|(_, s)| s.len()).sum();
        let mut samples: Vec<f64> = Vec::with_capacity(retained.min(SAMPLE_CAP));
        if retained <= SAMPLE_CAP {
            for (_, s) in &part_samples {
                samples.extend_from_slice(s);
            }
        } else {
            let n_total = all.n.max(1);
            for (n_part, s) in &part_samples {
                let take = ((SAMPLE_CAP as u128 * *n_part as u128 / n_total as u128) as usize)
                    .min(s.len());
                let mut sorted = s.to_vec();
                sorted.sort_by(|a, b| a.partial_cmp(b).unwrap_or(std::cmp::Ordering::Equal));
                samples.extend(subsample(&sorted, take));
            }
        }
        samples.sort_by(|a, b| a.partial_cmp(b).unwrap_or(std::cmp::Ordering::Equal));
        all.samples = samples;
        all
    }

    pub fn merge(&mut self, o: &Stats) {
        let (n_self, n_o) = (self.n, o.n);
        self.n += o.n;
        self.sum += o.sum;
        self.sum2 += o.sum2;
        self.min = self.min.min(o.min);
        self.max = self.max.max(o.max);
        if self.samples.len() + o.samples.len() <= SAMPLE_CAP {
            self.samples.extend_from_slice(&o.samples);
        } else {
            // weight each side by its *stream* length, not its reservoir
            // length, so a capped 10^6-sample stream is not outvoted by
            // an exact 10^3-sample one
            let n_total = (n_self + n_o).max(1);
            let take_self =
                ((SAMPLE_CAP as u128 * n_self as u128 / n_total as u128) as usize).min(SAMPLE_CAP);
            let take_o = SAMPLE_CAP - take_self;
            let mut merged = subsample(&self.samples, take_self);
            merged.extend(subsample(&o.samples, take_o));
            self.samples = merged;
        }
    }

    /// Several exact sample quantiles at once (one sort). Quantiles use
    /// linear interpolation between order statistics; `q` in [0, 1].
    /// Returns 0.0 per entry for an empty distribution.
    pub fn quantiles(&self, qs: &[f64]) -> Vec<f64> {
        if self.samples.is_empty() {
            return vec![0.0; qs.len()];
        }
        let mut s = self.samples.clone();
        s.sort_by(|a, b| a.partial_cmp(b).unwrap_or(std::cmp::Ordering::Equal));
        qs.iter()
            .map(|&q| {
                let pos = q.clamp(0.0, 1.0) * (s.len() - 1) as f64;
                let lo = pos.floor() as usize;
                let hi = pos.ceil() as usize;
                let frac = pos - lo as f64;
                s[lo] * (1.0 - frac) + s[hi] * frac
            })
            .collect()
    }

    /// Single exact sample quantile (sorts a copy; for several
    /// quantiles prefer [`Stats::quantiles`]).
    pub fn quantile(&self, q: f64) -> f64 {
        self.quantiles(&[q])[0]
    }

    /// Quantile that distinguishes "no data": `None` when the reservoir
    /// is empty. The plain accessors below keep returning 0.0 in that
    /// case (never NaN), so report formatting stays total.
    pub fn try_quantile(&self, q: f64) -> Option<f64> {
        if self.samples.is_empty() {
            None
        } else {
            Some(self.quantile(q))
        }
    }

    /// Read access to the retained reservoir (unordered). The
    /// observability wire export ships these so merged quantiles stay
    /// deterministic across a process boundary.
    pub fn samples(&self) -> &[f64] {
        &self.samples
    }

    /// Rebuild a `Stats` from exported parts (the inverse of reading
    /// the public moments plus [`Stats::samples`]); used by the wire
    /// codec to reconstruct a remote registry's histograms. The
    /// reservoir is truncated to the cap, so a hostile peer cannot make
    /// the receiver retain unbounded samples.
    pub fn from_parts(n: u64, sum: f64, sum2: f64, min: f64, max: f64, samples: Vec<f64>) -> Stats {
        let mut samples = samples;
        samples.truncate(SAMPLE_CAP);
        Stats { n, sum, sum2, min, max, samples, rng_state: 0x9E3779B97F4A7C15 }
    }

    pub fn p50(&self) -> f64 {
        self.quantile(0.50)
    }

    pub fn p95(&self) -> f64 {
        self.quantile(0.95)
    }

    pub fn p99(&self) -> f64 {
        self.quantile(0.99)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn breakdown_accumulates() {
        let mut b = Breakdown::new();
        b.add(Component::Gc, 2.0);
        b.add(Component::Gc, 1.0);
        b.add(Component::Optimize, 7.0);
        assert_eq!(b.get(Component::Gc), 3.0);
        assert_eq!(b.total(), 10.0);
        assert!((b.fraction(Component::Gc) - 0.3).abs() < 1e-12);
    }

    #[test]
    fn merge_and_scale() {
        let mut a = Breakdown::new();
        a.add(Component::GaFetch, 4.0);
        let mut b = Breakdown::new();
        b.add(Component::GaFetch, 2.0);
        b.add(Component::Scheduling, 1.0);
        a.merge(&b);
        assert_eq!(a.get(Component::GaFetch), 6.0);
        let half = a.scaled(0.5);
        assert_eq!(half.get(Component::GaFetch), 3.0);
        assert_eq!(half.get(Component::Scheduling), 0.5);
    }

    #[test]
    fn stats_moments() {
        let mut s = Stats::new();
        for x in [1.0, 2.0, 3.0, 4.0] {
            s.push(x);
        }
        assert_eq!(s.n, 4);
        assert!((s.mean() - 2.5).abs() < 1e-12);
        assert!((s.var() - 1.25).abs() < 1e-12);
        assert_eq!(s.min, 1.0);
        assert_eq!(s.max, 4.0);
    }

    #[test]
    fn empty_breakdown_fraction_zero() {
        let b = Breakdown::new();
        assert_eq!(b.fraction(Component::Gc), 0.0);
    }

    #[test]
    fn quantiles_exact_on_known_distribution() {
        let mut s = Stats::new();
        for x in 1..=100 {
            s.push(x as f64);
        }
        assert!((s.p50() - 50.5).abs() < 1e-9, "p50 {}", s.p50());
        assert!((s.quantile(0.0) - 1.0).abs() < 1e-12);
        assert!((s.quantile(1.0) - 100.0).abs() < 1e-12);
        assert!(s.p99() > 98.0 && s.p99() <= 100.0, "p99 {}", s.p99());
        assert!(s.p95() > 94.0 && s.p95() < 97.0, "p95 {}", s.p95());
        // order-independent: quantiles of a shuffled stream are equal
        let mut r = crate::prng::Rng::new(8);
        let mut xs: Vec<f64> = (1..=100).map(|x| x as f64).collect();
        r.shuffle(&mut xs);
        let mut s2 = Stats::new();
        for x in xs {
            s2.push(x);
        }
        assert_eq!(s.p50(), s2.p50());
        assert_eq!(s.p99(), s2.p99());
    }

    #[test]
    fn sample_memory_is_bounded_and_quantiles_stay_close() {
        let mut s = Stats::new();
        let n = 200_000u64;
        for x in 1..=n {
            s.push(x as f64);
        }
        assert_eq!(s.n, n);
        assert!(s.samples.len() <= super::SAMPLE_CAP, "reservoir overflow");
        // moments are exact regardless of the reservoir
        assert!((s.mean() - (n as f64 + 1.0) / 2.0).abs() < 1e-6);
        assert_eq!(s.max, n as f64);
        // reservoir quantile of a uniform ramp: within a few percent
        let p50 = s.p50();
        assert!(
            (p50 - n as f64 / 2.0).abs() < 0.05 * n as f64,
            "p50 {p50} too far from {}",
            n / 2
        );
        // merging two capped stats stays bounded too
        let mut t = s.clone();
        t.merge(&s);
        assert!(t.samples.len() <= super::SAMPLE_CAP);
        assert_eq!(t.n, 2 * n);
    }

    #[test]
    fn empty_reservoir_quantiles_are_zero_never_nan() {
        let s = Stats::new();
        for v in [s.p50(), s.p95(), s.p99(), s.quantile(0.0), s.quantile(1.0)] {
            assert_eq!(v, 0.0, "empty Stats must report 0.0, got {v}");
            assert!(!v.is_nan());
        }
        assert_eq!(s.try_quantile(0.5), None);
        assert_eq!(s.mean(), 0.0);
        assert_eq!(s.var(), 0.0);
    }

    #[test]
    fn single_sample_quantiles_are_that_sample() {
        let mut s = Stats::new();
        s.push(3.25);
        for v in [s.p50(), s.p95(), s.p99(), s.quantile(0.0), s.quantile(1.0)] {
            assert_eq!(v, 3.25);
            assert!(!v.is_nan());
        }
        assert_eq!(s.try_quantile(0.99), Some(3.25));
        assert_eq!(s.n, 1);
        assert_eq!(s.min, 3.25);
        assert_eq!(s.max, 3.25);
        assert_eq!(s.var(), 0.0, "single sample has no spread");
    }

    #[test]
    fn merge_all_folds_every_part() {
        let mut a = Stats::new();
        let mut b = Stats::new();
        for x in 1..=10 {
            a.push(x as f64);
        }
        for x in 11..=20 {
            b.push(x as f64);
        }
        let all = Stats::merge_all([&a, &b]);
        assert_eq!(all.n, 20);
        assert_eq!(all.min, 1.0);
        assert_eq!(all.max, 20.0);
        assert!((all.mean() - 10.5).abs() < 1e-12);
        let empty = Stats::merge_all(std::iter::empty::<&Stats>());
        assert_eq!(empty.n, 0);
        assert_eq!(empty.p50(), 0.0);
    }

    #[test]
    fn merge_all_is_order_and_partition_independent() {
        // a fixed multiset of "latencies", deterministically scrambled
        let xs: Vec<f64> = (0..5000u64)
            .map(|i| ((i.wrapping_mul(2654435761) % 10_000) as f64) * 1e-4)
            .collect();
        // partition A: round-robin over 4 "workers"
        let mut a: Vec<Stats> = (0..4).map(|_| Stats::new()).collect();
        for (i, &x) in xs.iter().enumerate() {
            a[i % 4].push(x);
        }
        // partition B: contiguous chunks over 7 "workers"
        let mut b: Vec<Stats> = (0..7).map(|_| Stats::new()).collect();
        for (i, &x) in xs.iter().enumerate() {
            b[i * 7 / xs.len()].push(x);
        }
        let merged_a = Stats::merge_all(&a);
        let merged_b = Stats::merge_all(&b);
        // fold order must not matter either
        let mut a_rev: Vec<&Stats> = a.iter().collect();
        a_rev.reverse();
        let merged_a_rev = Stats::merge_all(a_rev);
        for q in [0.0, 0.25, 0.5, 0.95, 0.99, 1.0] {
            let qa = merged_a.quantile(q);
            assert_eq!(qa, merged_b.quantile(q), "partition changed q{q}");
            assert_eq!(qa, merged_a_rev.quantile(q), "fold order changed q{q}");
        }
        assert_eq!(merged_a.n, xs.len() as u64);
        assert_eq!(merged_b.n, xs.len() as u64);
        // and below the cap the merge is exact: equal to one big Stats
        let mut whole = Stats::new();
        for &x in &xs {
            whole.push(x);
        }
        assert_eq!(merged_a.p50(), whole.p50());
        assert_eq!(merged_a.p99(), whole.p99());
    }

    #[test]
    fn merge_all_past_the_cap_is_bounded_and_deterministic() {
        // two parts whose union exceeds SAMPLE_CAP (each part exact)
        let make = |lo: u64, hi: u64| {
            let mut s = Stats::new();
            for x in lo..hi {
                s.push(x as f64);
            }
            s
        };
        let a = make(0, super::SAMPLE_CAP as u64);
        let b = make(super::SAMPLE_CAP as u64, 2 * super::SAMPLE_CAP as u64);
        let ab = Stats::merge_all([&a, &b]);
        let ba = Stats::merge_all([&b, &a]);
        assert!(ab.samples.len() <= super::SAMPLE_CAP);
        assert_eq!(ab.n, 2 * super::SAMPLE_CAP as u64);
        assert_eq!(ab.p50(), ba.p50(), "cap thinning must be order-independent");
        assert_eq!(ab.p99(), ba.p99());
        // the strided order statistics stay close to the true quantiles
        let true_p50 = super::SAMPLE_CAP as f64;
        assert!((ab.p50() - true_p50).abs() < 0.02 * true_p50, "p50 {}", ab.p50());
    }

    #[test]
    fn quantiles_merge_and_empty() {
        let empty = Stats::new();
        assert_eq!(empty.p50(), 0.0);
        let mut a = Stats::new();
        let mut b = Stats::new();
        for x in 1..=50 {
            a.push(x as f64);
        }
        for x in 51..=100 {
            b.push(x as f64);
        }
        a.merge(&b);
        assert_eq!(a.n, 100);
        assert!((a.p50() - 50.5).abs() < 1e-9);
    }
}
