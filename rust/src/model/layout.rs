//! The variational-parameter layout — the Rust mirror of
//! `python/compile/constants.py`.
//!
//! `runtime::manifest` checks every value here against
//! `artifacts/manifest.json` at startup so the two sides cannot drift.

/// Number of filter bands (SDSS ugriz).
pub const N_BANDS: usize = 5;
/// Reference band index (r-band).
pub const REF_BAND: usize = 2;
/// Patch height/width in pixels.
pub const PATCH: usize = 32;
/// PSF Gaussian components per band.
pub const K_PSF: usize = 2;
/// Parameters per PSF component: (w, dx, dy, cxx, cxy, cyy).
pub const PSF_PARAMS: usize = 6;
/// Gaussian components per galaxy radial profile.
pub const K_PROFILE: usize = 4;
/// Effective star components per band.
pub const K_STAR: usize = K_PSF;
/// Effective galaxy components per band.
pub const K_GAL: usize = 2 * K_PROFILE * K_PSF;
/// Parameters per effective component: (w_eff, mx, my, p00, p01, p11).
pub const COMP_PARAMS: usize = 6;
/// Number of colors.
pub const N_COLORS: usize = 4;

/// θ entries per light source.
pub const DIM: usize = 27;
/// prior vector length.
pub const PRIOR_DIM: usize = 21;
/// KL ridge on location/shape entries.
pub const RIDGE: f64 = 1e-4;

/// Gaussian priors on the point-estimated galaxy shape parameters
/// (mean, variance in the unconstrained parameterization), weighted by
/// q(a = galaxy). See python/compile/constants.py for rationale.
pub const SHAPE_PRIOR_PDEV: (f64, f64) = (0.0, 4.0);
pub const SHAPE_PRIOR_AXIS: (f64, f64) = (0.0, 4.0);
pub const SHAPE_PRIOR_SCALE: (f64, f64) = (0.5, 0.25);

// θ offsets
pub const I_A: usize = 0;
pub const I_LOC: usize = 1;
pub const I_FLUX_STAR: usize = 3;
pub const I_FLUX_GAL: usize = 5;
pub const I_COLOR_MEAN_STAR: usize = 7;
pub const I_COLOR_MEAN_GAL: usize = 11;
pub const I_COLOR_VAR_STAR: usize = 15;
pub const I_COLOR_VAR_GAL: usize = 19;
pub const I_SHAPE: usize = 23;

// prior offsets
pub const P_A: usize = 0;
pub const P_FLUX_STAR: usize = 1;
pub const P_FLUX_GAL: usize = 3;
pub const P_COLOR_MEAN_STAR: usize = 5;
pub const P_COLOR_MEAN_GAL: usize = 9;
pub const P_COLOR_VAR_STAR: usize = 13;
pub const P_COLOR_VAR_GAL: usize = 17;

/// Galaxy profile mixture tables (amplitude, variance in units of the
/// half-light radius squared); amplitudes sum to 1 per profile.
pub const PROFILE_EXP_AMP: [f64; K_PROFILE] = [0.30, 0.40, 0.25, 0.05];
pub const PROFILE_EXP_VAR: [f64; K_PROFILE] = [0.12, 0.50, 1.30, 3.00];
pub const PROFILE_DEV_AMP: [f64; K_PROFILE] = [0.35, 0.35, 0.20, 0.10];
pub const PROFILE_DEV_VAR: [f64; K_PROFILE] = [0.03, 0.25, 1.20, 6.00];

/// Band flux mapping: log l_b = log r + COLOR_COEF[b] · c.
pub const COLOR_COEF: [[f64; N_COLORS]; N_BANDS] = [
    [-1.0, -1.0, 0.0, 0.0],
    [0.0, -1.0, 0.0, 0.0],
    [0.0, 0.0, 0.0, 0.0],
    [0.0, 0.0, 1.0, 0.0],
    [0.0, 0.0, 1.0, 1.0],
];

/// Artifact basenames.
pub const ART_LIKE_AD: &str = "like_ad";
pub const ART_LIKE_PALLAS: &str = "like_pallas";
pub const ART_KL: &str = "kl";
pub const ART_RENDER: &str = "render_pallas";

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn layout_is_contiguous() {
        assert_eq!(I_A, 0);
        assert_eq!(I_LOC, I_A + 1);
        assert_eq!(I_FLUX_STAR, I_LOC + 2);
        assert_eq!(I_FLUX_GAL, I_FLUX_STAR + 2);
        assert_eq!(I_COLOR_MEAN_STAR, I_FLUX_GAL + 2);
        assert_eq!(I_COLOR_MEAN_GAL, I_COLOR_MEAN_STAR + N_COLORS);
        assert_eq!(I_COLOR_VAR_STAR, I_COLOR_MEAN_GAL + N_COLORS);
        assert_eq!(I_COLOR_VAR_GAL, I_COLOR_VAR_STAR + N_COLORS);
        assert_eq!(I_SHAPE, I_COLOR_VAR_GAL + N_COLORS);
        assert_eq!(DIM, I_SHAPE + 4);
    }

    #[test]
    fn prior_layout_is_contiguous() {
        assert_eq!(P_FLUX_STAR, P_A + 1);
        assert_eq!(PRIOR_DIM, P_COLOR_VAR_GAL + N_COLORS);
    }

    #[test]
    fn profile_amps_normalized() {
        let se: f64 = PROFILE_EXP_AMP.iter().sum();
        let sd: f64 = PROFILE_DEV_AMP.iter().sum();
        assert!((se - 1.0).abs() < 1e-12);
        assert!((sd - 1.0).abs() < 1e-12);
    }

    #[test]
    fn ref_band_has_zero_color_coef() {
        assert!(COLOR_COEF[REF_BAND].iter().all(|&c| c == 0.0));
    }
}
