//! Native Gaussian-mixture rendering (the Rust twin of the L1 kernel).
//!
//! Used on paths where Python can never run: synthetic-sky generation,
//! neighbor-background rendering during optimization, and the Photo
//! baseline. Parity with the Pallas kernel is enforced by the
//! `render_parity` integration test (same components → same image).

use super::comps::EffComp;

/// A rectangle of pixels in global sky coordinates: pixel (r, c) of the
/// buffer has center (x0 + c + 0.5, y0 + r + 0.5).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct PixelRect {
    pub x0: f64,
    pub y0: f64,
    pub rows: usize,
    pub cols: usize,
}

impl PixelRect {
    pub fn len(&self) -> usize {
        self.rows * self.cols
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Intersection with another rect of *integer* extents, in global
    /// coordinates. Returns None if disjoint.
    pub fn intersect(&self, other: &PixelRect) -> Option<PixelRect> {
        let x0 = self.x0.max(other.x0);
        let y0 = self.y0.max(other.y0);
        let x1 = (self.x0 + self.cols as f64).min(other.x0 + other.cols as f64);
        let y1 = (self.y0 + self.rows as f64).min(other.y0 + other.rows as f64);
        if x1 <= x0 || y1 <= y0 {
            return None;
        }
        Some(PixelRect {
            x0,
            y0,
            rows: (y1 - y0).round() as usize,
            cols: (x1 - x0).round() as usize,
        })
    }
}

/// Accumulate `amp * mixture(comps)` into `out` over `rect`.
///
/// Components are skipped per-row once their Mahalanobis distance bound
/// exceeds `CUTOFF` (mixture tails are negligible); this is the renderer's
/// main optimization and is validated against the exact oracle in tests.
pub fn accumulate_mixture(out: &mut [f64], rect: &PixelRect, comps: &[EffComp], amp: f64) {
    assert_eq!(out.len(), rect.len());
    if amp == 0.0 {
        return;
    }
    for comp in comps {
        let &[w, mx, my, p00, p01, p11] = comp;
        if w == 0.0 {
            continue;
        }
        let wa = w * amp;
        for r in 0..rect.rows {
            let y = rect.y0 + r as f64 + 0.5;
            let dy = y - my;
            let row = &mut out[r * rect.cols..(r + 1) * rect.cols];
            for (c, px) in row.iter_mut().enumerate() {
                let x = rect.x0 + c as f64 + 0.5;
                let dx = x - mx;
                let q = p00 * dx * dx + 2.0 * p01 * dx * dy + p11 * dy * dy;
                if q < 2.0 * MAX_EXP {
                    *px += wa * (-0.5 * q).exp();
                }
            }
        }
    }
}

/// Beyond this quadratic-form value exp(-q/2) underflows any meaningful
/// contribution (exp(-60) ≈ 9e-27).
const MAX_EXP: f64 = 60.0;

/// Render a mixture into a fresh buffer.
pub fn render_mixture(rect: &PixelRect, comps: &[EffComp], amp: f64) -> Vec<f64> {
    let mut out = vec![0.0; rect.len()];
    accumulate_mixture(&mut out, rect, comps, amp);
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::comps::{galaxy_comps, mixture_integral, star_comps, PsfBand};
    use crate::model::params::GalaxyShape;

    fn test_psf() -> PsfBand {
        [
            [0.7, 0.0, 0.0, 1.0, 0.05, 1.0],
            [0.3, 0.1, -0.1, 2.5, -0.1, 2.5],
        ]
    }

    #[test]
    fn well_contained_star_sums_to_flux() {
        let rect = PixelRect { x0: 0.0, y0: 0.0, rows: 64, cols: 64 };
        let comps = star_comps((32.0, 32.0), &test_psf());
        let img = render_mixture(&rect, &comps, 7.5);
        let total: f64 = img.iter().sum();
        assert!((total - 7.5).abs() / 7.5 < 1e-3, "total {total}");
    }

    #[test]
    fn galaxy_peak_at_center() {
        let rect = PixelRect { x0: 0.0, y0: 0.0, rows: 32, cols: 32 };
        let shape = GalaxyShape { p_dev: 0.5, axis_ratio: 0.8, angle: 0.3, scale: 2.0 };
        let comps = galaxy_comps((16.0, 16.0), &test_psf(), &shape);
        let img = render_mixture(&rect, &comps, 1.0);
        let (mut best, mut arg) = (f64::MIN, 0);
        for (i, &v) in img.iter().enumerate() {
            if v > best {
                best = v;
                arg = i;
            }
        }
        // center pixel (15..16, 15..16) region
        let (r, c) = (arg / 32, arg % 32);
        assert!((14..=17).contains(&r) && (14..=17).contains(&c), "peak at ({r},{c})");
    }

    #[test]
    fn rect_offset_consistency() {
        // rendering a shifted rect samples the same global function
        let comps = star_comps((20.0, 20.0), &test_psf());
        let r1 = PixelRect { x0: 0.0, y0: 0.0, rows: 40, cols: 40 };
        let r2 = PixelRect { x0: 10.0, y0: 10.0, rows: 20, cols: 20 };
        let img1 = render_mixture(&r1, &comps, 3.0);
        let img2 = render_mixture(&r2, &comps, 3.0);
        for r in 0..20 {
            for c in 0..20 {
                let a = img1[(r + 10) * 40 + (c + 10)];
                let b = img2[r * 20 + c];
                assert!((a - b).abs() < 1e-12);
            }
        }
    }

    #[test]
    fn cutoff_preserves_mass() {
        // cutoff must not visibly distort a contained source
        let rect = PixelRect { x0: 0.0, y0: 0.0, rows: 96, cols: 96 };
        let shape = GalaxyShape { p_dev: 0.7, axis_ratio: 0.5, angle: 1.0, scale: 3.0 };
        let comps = galaxy_comps((48.0, 48.0), &test_psf(), &shape);
        let img = render_mixture(&rect, &comps, 1.0);
        let total: f64 = img.iter().sum();
        assert!((mixture_integral(&comps) - 1.0).abs() < 1e-9);
        assert!((total - 1.0).abs() < 5e-3, "total {total}");
    }

    #[test]
    fn intersect_basic() {
        let a = PixelRect { x0: 0.0, y0: 0.0, rows: 10, cols: 10 };
        let b = PixelRect { x0: 5.0, y0: 8.0, rows: 10, cols: 10 };
        let i = a.intersect(&b).unwrap();
        assert_eq!((i.x0, i.y0), (5.0, 8.0));
        assert_eq!((i.rows, i.cols), (2, 5));
        let c = PixelRect { x0: 100.0, y0: 0.0, rows: 4, cols: 4 };
        assert!(a.intersect(&c).is_none());
    }
}
