//! The Celeste statistical model — Rust side.
//!
//! The differentiable ELBO lives in Python (`python/compile/model.py`) and
//! reaches Rust only as compiled HLO artifacts; this module carries
//! everything the coordinator needs natively: the parameter layout, the
//! physical-parameter types, effective Gaussian components, and a native
//! renderer for synthetic data and neighbor backgrounds.

pub mod comps;
pub mod layout;
pub mod params;
pub mod render;

pub use comps::{band_loglum_moments, galaxy_comps, star_comps, EffComp, PsfBand};
pub use params::{
    extract_estimate, sigmoid, theta_init, Estimate, GalaxyShape, Prior, SourceParams,
};
pub use render::{accumulate_mixture, render_mixture, PixelRect};
