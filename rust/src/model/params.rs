//! Physical source parameters, the variational vector θ, and priors.

use super::layout as L;

/// Galaxy shape parameters (constrained, physical).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct GalaxyShape {
    /// de Vaucouleurs mixture weight in [0, 1] ("profile")
    pub p_dev: f64,
    /// minor/major axis ratio in (0, 1) ("eccentricity" in the paper's table)
    pub axis_ratio: f64,
    /// position angle, radians
    pub angle: f64,
    /// effective (half-light) radius, pixels ("scale")
    pub scale: f64,
}

impl GalaxyShape {
    pub fn point_like() -> Self {
        GalaxyShape { p_dev: 0.5, axis_ratio: 0.7, angle: 0.0, scale: 1.0 }
    }
}

/// Ground-truth physical parameters of one light source (what the sky
/// simulator draws and what catalogs estimate).
#[derive(Clone, Debug)]
pub struct SourceParams {
    /// global sky position, pixel units
    pub pos: (f64, f64),
    pub is_galaxy: bool,
    /// reference-band flux (linear units)
    pub flux_r: f64,
    /// colors: log ratios of adjacent-band fluxes
    pub colors: [f64; L::N_COLORS],
    pub shape: GalaxyShape,
}

impl SourceParams {
    /// Flux in an arbitrary band via the color mapping.
    pub fn flux_in_band(&self, band: usize) -> f64 {
        let mut lg = self.flux_r.ln();
        for (i, &c) in L::COLOR_COEF[band].iter().enumerate() {
            lg += c * self.colors[i];
        }
        lg.exp()
    }
}

#[inline]
pub fn sigmoid(x: f64) -> f64 {
    1.0 / (1.0 + (-x).exp())
}

#[inline]
pub fn logit(p: f64) -> f64 {
    (p / (1.0 - p)).ln()
}

/// Initial variance used for q(log r) and q(c) when initializing θ from a
/// catalog point estimate.
pub const INIT_FLUX_VAR: f64 = 0.25;
pub const INIT_COLOR_VAR: f64 = 0.09;

/// Build an initial θ from a (possibly noisy) catalog estimate. The patch
/// is centered on the estimate, so the location offset starts at 0.
pub fn theta_init(est: &SourceParams, p_gal_guess: f64) -> [f64; L::DIM] {
    let mut t = [0.0; L::DIM];
    t[L::I_A] = logit(p_gal_guess.clamp(1e-4, 1.0 - 1e-4));
    // E[r] = exp(mu + var/2)  =>  mu = ln(flux) - var/2
    let mu = est.flux_r.max(1e-3).ln() - INIT_FLUX_VAR / 2.0;
    t[L::I_FLUX_STAR] = mu;
    t[L::I_FLUX_STAR + 1] = INIT_FLUX_VAR.ln();
    t[L::I_FLUX_GAL] = mu;
    t[L::I_FLUX_GAL + 1] = INIT_FLUX_VAR.ln();
    for i in 0..L::N_COLORS {
        t[L::I_COLOR_MEAN_STAR + i] = est.colors[i];
        t[L::I_COLOR_MEAN_GAL + i] = est.colors[i];
        t[L::I_COLOR_VAR_STAR + i] = INIT_COLOR_VAR.ln();
        t[L::I_COLOR_VAR_GAL + i] = INIT_COLOR_VAR.ln();
    }
    t[L::I_SHAPE] = logit(est.shape.p_dev.clamp(0.02, 0.98));
    t[L::I_SHAPE + 1] = logit(est.shape.axis_ratio.clamp(0.02, 0.98));
    t[L::I_SHAPE + 2] = est.shape.angle;
    t[L::I_SHAPE + 3] = est.shape.scale.max(0.05).ln();
    t
}

/// Posterior point estimates extracted from an optimized θ (the catalog
/// entry Celeste reports).
#[derive(Clone, Debug)]
pub struct Estimate {
    /// probability the source is a galaxy
    pub p_gal: f64,
    /// location offset from the patch center, pixels
    pub d_pos: (f64, f64),
    /// posterior mean reference-band flux (type-marginalized)
    pub flux_r: f64,
    /// type-marginalized posterior mean colors
    pub colors: [f64; L::N_COLORS],
    pub shape: GalaxyShape,
}

pub fn extract_estimate(t: &[f64; L::DIM]) -> Estimate {
    let g = sigmoid(t[L::I_A]);
    let flux = |mu: f64, logvar: f64| (mu + 0.5 * logvar.exp()).exp();
    let fs = flux(t[L::I_FLUX_STAR], t[L::I_FLUX_STAR + 1]);
    let fg = flux(t[L::I_FLUX_GAL], t[L::I_FLUX_GAL + 1]);
    let mut colors = [0.0; L::N_COLORS];
    for i in 0..L::N_COLORS {
        colors[i] = (1.0 - g) * t[L::I_COLOR_MEAN_STAR + i] + g * t[L::I_COLOR_MEAN_GAL + i];
    }
    Estimate {
        p_gal: g,
        d_pos: (t[L::I_LOC], t[L::I_LOC + 1]),
        flux_r: (1.0 - g) * fs + g * fg,
        colors,
        shape: GalaxyShape {
            p_dev: sigmoid(t[L::I_SHAPE]),
            axis_ratio: sigmoid(t[L::I_SHAPE + 1]),
            angle: t[L::I_SHAPE + 2],
            scale: t[L::I_SHAPE + 3].exp(),
        },
    }
}

/// Prior hyperparameters (paper: "learned from pre-existing catalogs").
#[derive(Clone, Debug)]
pub struct Prior {
    pub p_gal: f64,
    pub flux_star: (f64, f64),
    pub flux_gal: (f64, f64),
    pub color_mean_star: [f64; L::N_COLORS],
    pub color_mean_gal: [f64; L::N_COLORS],
    pub color_var_star: [f64; L::N_COLORS],
    pub color_var_gal: [f64; L::N_COLORS],
}

impl Default for Prior {
    fn default() -> Self {
        Prior {
            p_gal: 0.3,
            flux_star: (4.0, 2.0),
            flux_gal: (4.5, 2.0),
            color_mean_star: [0.5, 0.4, 0.2, 0.1],
            color_mean_gal: [0.8, 0.5, 0.3, 0.2],
            color_var_star: [0.04; L::N_COLORS],
            color_var_gal: [0.04; L::N_COLORS],
        }
    }
}

impl Prior {
    /// Flatten to the artifact's prior-vector layout.
    pub fn to_vec(&self) -> [f64; L::PRIOR_DIM] {
        let mut v = [0.0; L::PRIOR_DIM];
        v[L::P_A] = self.p_gal;
        v[L::P_FLUX_STAR] = self.flux_star.0;
        v[L::P_FLUX_STAR + 1] = self.flux_star.1;
        v[L::P_FLUX_GAL] = self.flux_gal.0;
        v[L::P_FLUX_GAL + 1] = self.flux_gal.1;
        for i in 0..L::N_COLORS {
            v[L::P_COLOR_MEAN_STAR + i] = self.color_mean_star[i];
            v[L::P_COLOR_MEAN_GAL + i] = self.color_mean_gal[i];
            v[L::P_COLOR_VAR_STAR + i] = self.color_var_star[i];
            v[L::P_COLOR_VAR_GAL + i] = self.color_var_gal[i];
        }
        v
    }

    /// Fit priors by moment-matching a catalog of sources (the paper's
    /// "parameters learned from pre-existing astronomical catalogs").
    pub fn fit(sources: &[SourceParams]) -> Prior {
        let mut p = Prior::default();
        let (mut ns, mut ng) = (0usize, 0usize);
        let mut acc = |v: &mut (f64, f64, usize), x: f64| {
            v.0 += x;
            v.1 += x * x;
            v.2 += 1;
        };
        let mut fs = (0.0, 0.0, 0usize);
        let mut fg = (0.0, 0.0, 0usize);
        let mut cms = [(0.0, 0.0, 0usize); L::N_COLORS];
        let mut cmg = [(0.0, 0.0, 0usize); L::N_COLORS];
        for s in sources {
            let lf = s.flux_r.max(1e-3).ln();
            if s.is_galaxy {
                ng += 1;
                acc(&mut fg, lf);
                for i in 0..L::N_COLORS {
                    acc(&mut cmg[i], s.colors[i]);
                }
            } else {
                ns += 1;
                acc(&mut fs, lf);
                for i in 0..L::N_COLORS {
                    acc(&mut cms[i], s.colors[i]);
                }
            }
        }
        let finish = |v: (f64, f64, usize)| -> (f64, f64) {
            if v.2 < 2 {
                return (4.0, 2.0);
            }
            let m = v.0 / v.2 as f64;
            ((m), (v.1 / v.2 as f64 - m * m).max(0.05))
        };
        if ns + ng > 0 {
            p.p_gal = (ng as f64 / (ns + ng) as f64).clamp(0.02, 0.98);
        }
        p.flux_star = finish(fs);
        p.flux_gal = finish(fg);
        for i in 0..L::N_COLORS {
            let (m, v) = finish(cms[i]);
            p.color_mean_star[i] = m;
            p.color_var_star[i] = v;
            let (m, v) = finish(cmg[i]);
            p.color_mean_gal[i] = m;
            p.color_var_gal[i] = v;
        }
        p
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sigmoid_logit_roundtrip() {
        for &p in &[0.01, 0.3, 0.5, 0.9, 0.99] {
            assert!((sigmoid(logit(p)) - p).abs() < 1e-12);
        }
    }

    #[test]
    fn flux_in_band_ref_is_flux_r() {
        let s = SourceParams {
            pos: (0.0, 0.0),
            is_galaxy: false,
            flux_r: 123.0,
            colors: [0.5, -0.2, 0.3, 0.1],
            shape: GalaxyShape::point_like(),
        };
        assert!((s.flux_in_band(L::REF_BAND) - 123.0).abs() < 1e-9);
        // adjacent band: flux_3 = flux_r * exp(c_2)
        assert!((s.flux_in_band(3) - 123.0 * 0.3f64.exp()).abs() < 1e-9);
    }

    #[test]
    fn theta_init_extract_roundtrip() {
        let s = SourceParams {
            pos: (10.0, 20.0),
            is_galaxy: true,
            flux_r: 80.0,
            colors: [0.4, 0.1, -0.1, 0.2],
            shape: GalaxyShape { p_dev: 0.6, axis_ratio: 0.5, angle: 0.7, scale: 2.0 },
        };
        let t = theta_init(&s, 0.5);
        let e = extract_estimate(&t);
        assert!((e.p_gal - 0.5).abs() < 1e-9);
        assert!((e.flux_r - 80.0).abs() / 80.0 < 1e-6);
        for i in 0..4 {
            assert!((e.colors[i] - s.colors[i]).abs() < 1e-9);
        }
        assert!((e.shape.scale - 2.0).abs() < 1e-9);
        assert!((e.shape.axis_ratio - 0.5).abs() < 1e-9);
    }

    #[test]
    fn prior_vec_layout() {
        let p = Prior::default();
        let v = p.to_vec();
        assert_eq!(v[L::P_A], 0.3);
        assert_eq!(v[L::P_FLUX_GAL], 4.5);
        assert_eq!(v[L::P_COLOR_VAR_GAL + 3], 0.04);
    }

    #[test]
    fn prior_fit_moment_matching() {
        let mk = |is_galaxy: bool, flux: f64| SourceParams {
            pos: (0.0, 0.0),
            is_galaxy,
            flux_r: flux,
            colors: [0.2; 4],
            shape: GalaxyShape::point_like(),
        };
        let mut srcs = vec![];
        for i in 0..100 {
            srcs.push(mk(i % 4 == 0, 50.0 + i as f64));
        }
        let p = Prior::fit(&srcs);
        assert!((p.p_gal - 0.25).abs() < 0.01);
        assert!(p.flux_star.0 > 3.0 && p.flux_star.0 < 6.0);
        assert!((p.color_mean_gal[0] - 0.2).abs() < 1e-9);
    }
}
