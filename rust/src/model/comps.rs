//! Effective Gaussian components — the Rust mirror of
//! `model.build_inputs` on the Python side (validated for parity by the
//! integration test against the `render_pallas` artifact).

use super::layout as L;
use super::params::GalaxyShape;

/// One effective (post-PSF-convolution) Gaussian component:
/// (w_eff, mx, my, p00, p01, p11) — weight with the bivariate-normal
/// normalization folded in, mean, and precision entries.
pub type EffComp = [f64; L::COMP_PARAMS];

/// One PSF component: (w, dx, dy, cxx, cxy, cyy).
pub type PsfComp = [f64; L::PSF_PARAMS];
/// Per-band PSF.
pub type PsfBand = [PsfComp; L::K_PSF];

/// Fold the normalization into the weight and invert the covariance.
fn fold_norm(w: f64, cxx: f64, cxy: f64, cyy: f64) -> (f64, f64, f64, f64) {
    let det = cxx * cyy - cxy * cxy;
    debug_assert!(det > 0.0, "covariance not PD: {cxx} {cxy} {cyy}");
    let w_eff = w / (2.0 * std::f64::consts::PI * det.sqrt());
    (w_eff, cyy / det, -cxy / det, cxx / det)
}

/// Star components: the PSF translated to `center`.
pub fn star_comps(center: (f64, f64), psf: &PsfBand) -> [EffComp; L::K_STAR] {
    let mut out = [[0.0; L::COMP_PARAMS]; L::K_STAR];
    for (o, p) in out.iter_mut().zip(psf.iter()) {
        let (w_eff, p00, p01, p11) = fold_norm(p[0], p[3], p[4], p[5]);
        *o = [w_eff, center.0 + p[1], center.1 + p[2], p00, p01, p11];
    }
    out
}

/// Unit-profile galaxy covariance: scale² R diag(1, q²) Rᵀ.
pub fn galaxy_base_cov(shape: &GalaxyShape) -> (f64, f64, f64) {
    let (s, c) = shape.angle.sin_cos();
    let s1 = shape.scale * shape.scale;
    let s2 = s1 * shape.axis_ratio * shape.axis_ratio;
    (
        c * c * s1 + s * s * s2,
        c * s * (s1 - s2),
        s * s * s1 + c * c * s2,
    )
}

/// Galaxy components: each profile component convolved with each PSF
/// component (Gaussian ⊛ Gaussian, analytic).
pub fn galaxy_comps(
    center: (f64, f64),
    psf: &PsfBand,
    shape: &GalaxyShape,
) -> [EffComp; L::K_GAL] {
    let (vxx, vxy, vyy) = galaxy_base_cov(shape);
    let mut out = [[0.0; L::COMP_PARAMS]; L::K_GAL];
    let mut idx = 0;
    let profiles: [(&[f64; L::K_PROFILE], &[f64; L::K_PROFILE], f64); 2] = [
        (&L::PROFILE_EXP_AMP, &L::PROFILE_EXP_VAR, 1.0 - shape.p_dev),
        (&L::PROFILE_DEV_AMP, &L::PROFILE_DEV_VAR, shape.p_dev),
    ];
    for (amps, vars, mix) in profiles {
        for i in 0..L::K_PROFILE {
            for p in psf.iter() {
                let w = amps[i] * mix * p[0];
                let cxx = vars[i] * vxx + p[3];
                let cxy = vars[i] * vxy + p[4];
                let cyy = vars[i] * vyy + p[5];
                let (w_eff, p00, p01, p11) = fold_norm(w, cxx, cxy, cyy);
                out[idx] = [w_eff, center.0 + p[1], center.1 + p[2], p00, p01, p11];
                idx += 1;
            }
        }
    }
    debug_assert_eq!(idx, L::K_GAL);
    out
}

/// First and second moments of the per-band luminosity under the
/// variational lognormal/color factors (mirror of
/// `ref.band_loglum_moments`).
pub fn band_loglum_moments(
    flux_mean: f64,
    flux_var: f64,
    color_mean: &[f64; L::N_COLORS],
    color_var: &[f64; L::N_COLORS],
) -> ([f64; L::N_BANDS], [f64; L::N_BANDS]) {
    let mut m1 = [0.0; L::N_BANDS];
    let mut m2 = [0.0; L::N_BANDS];
    for b in 0..L::N_BANDS {
        let mut m = flux_mean;
        let mut v = flux_var;
        for i in 0..L::N_COLORS {
            m += L::COLOR_COEF[b][i] * color_mean[i];
            v += L::COLOR_COEF[b][i].abs() * color_var[i];
        }
        m1[b] = (m + 0.5 * v).exp();
        m2[b] = (2.0 * m + 2.0 * v).exp();
    }
    (m1, m2)
}

/// Analytic integral of an effective-component mixture over the plane.
pub fn mixture_integral(comps: &[EffComp]) -> f64 {
    comps
        .iter()
        .map(|c| {
            let det_p = c[3] * c[5] - c[4] * c[4];
            c[0] * 2.0 * std::f64::consts::PI / det_p.sqrt()
        })
        .sum()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn test_psf() -> PsfBand {
        [
            [0.7, 0.0, 0.0, 1.0, 0.05, 1.0],
            [0.3, 0.1, -0.1, 2.5, -0.1, 2.5],
        ]
    }

    #[test]
    fn star_mixture_integrates_to_one() {
        let comps = star_comps((16.0, 16.0), &test_psf());
        assert!((mixture_integral(&comps) - 1.0).abs() < 1e-12);
    }

    #[test]
    fn galaxy_mixture_integrates_to_one() {
        let shape = GalaxyShape { p_dev: 0.4, axis_ratio: 0.6, angle: 0.9, scale: 2.3 };
        let comps = galaxy_comps((16.0, 16.0), &test_psf(), &shape);
        assert!((mixture_integral(&comps) - 1.0).abs() < 1e-10);
    }

    #[test]
    fn galaxy_cov_round_source_is_isotropic() {
        let shape = GalaxyShape { p_dev: 0.5, axis_ratio: 1.0 - 1e-12, angle: 1.2, scale: 2.0 };
        let (vxx, vxy, vyy) = galaxy_base_cov(&shape);
        assert!((vxx - 4.0).abs() < 1e-6);
        assert!((vyy - 4.0).abs() < 1e-6);
        assert!(vxy.abs() < 1e-6);
    }

    #[test]
    fn galaxy_cov_angle_rotates() {
        let shape0 = GalaxyShape { p_dev: 0.5, axis_ratio: 0.5, angle: 0.0, scale: 2.0 };
        let (vxx0, _, vyy0) = galaxy_base_cov(&shape0);
        assert!(vxx0 > vyy0); // major axis along x at angle 0
        let shape90 = GalaxyShape { angle: std::f64::consts::FRAC_PI_2, ..shape0 };
        let (vxx9, _, vyy9) = galaxy_base_cov(&shape90);
        assert!((vxx9 - vyy0).abs() < 1e-9);
        assert!((vyy9 - vxx0).abs() < 1e-9);
    }

    #[test]
    fn moments_ref_band_only_flux() {
        let (m1, _) = band_loglum_moments(2.0, 0.5, &[9.0; 4], &[3.0; 4]);
        assert!((m1[L::REF_BAND] - (2.0f64 + 0.25).exp()).abs() < 1e-9);
    }

    #[test]
    fn moments_second_ge_first_squared() {
        let (m1, m2) = band_loglum_moments(1.0, 0.3, &[0.2, -0.1, 0.4, 0.0], &[0.1; 4]);
        for b in 0..L::N_BANDS {
            assert!(m2[b] >= m1[b] * m1[b]); // Jensen
        }
    }
}
