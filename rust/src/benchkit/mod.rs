//! Tiny benchmarking harness (the offline registry has no criterion).
//!
//! `cargo bench` targets use `harness = false` and call [`bench`] /
//! [`bench_n`] directly; output is one line per case with throughput.

use std::time::Instant;

/// Result of one benchmark case.
#[derive(Clone, Debug)]
pub struct BenchResult {
    pub name: String,
    pub iters: u64,
    pub total_secs: f64,
    pub ns_per_iter: f64,
}

impl BenchResult {
    pub fn per_sec(&self) -> f64 {
        1e9 / self.ns_per_iter
    }
}

/// Run `f` repeatedly for ~`target_secs`, after a warmup, and report.
pub fn bench<F: FnMut()>(name: &str, target_secs: f64, mut f: F) -> BenchResult {
    // warmup + calibration
    let t0 = Instant::now();
    f();
    let once = t0.elapsed().as_secs_f64().max(1e-9);
    let iters = ((target_secs / once).ceil() as u64).clamp(1, 1_000_000);
    let t1 = Instant::now();
    for _ in 0..iters {
        f();
    }
    let total = t1.elapsed().as_secs_f64();
    let r = BenchResult {
        name: name.to_string(),
        iters,
        total_secs: total,
        ns_per_iter: total * 1e9 / iters as f64,
    };
    print_result(&r);
    r
}

/// Run `f` exactly `iters` times.
pub fn bench_n<F: FnMut()>(name: &str, iters: u64, mut f: F) -> BenchResult {
    let t0 = Instant::now();
    f(); // warmup
    let _ = t0;
    let t1 = Instant::now();
    for _ in 0..iters {
        f();
    }
    let total = t1.elapsed().as_secs_f64();
    let r = BenchResult {
        name: name.to_string(),
        iters,
        total_secs: total,
        ns_per_iter: total * 1e9 / iters as f64,
    };
    print_result(&r);
    r
}

fn print_result(r: &BenchResult) {
    let (val, unit) = if r.ns_per_iter >= 1e9 {
        (r.ns_per_iter / 1e9, "s")
    } else if r.ns_per_iter >= 1e6 {
        (r.ns_per_iter / 1e6, "ms")
    } else if r.ns_per_iter >= 1e3 {
        (r.ns_per_iter / 1e3, "us")
    } else {
        (r.ns_per_iter, "ns")
    };
    println!(
        "bench {:<42} {:>10.3} {}/iter ({:>12.1} /s, {} iters)",
        r.name,
        val,
        unit,
        r.per_sec(),
        r.iters
    );
}

/// Prevent the optimizer from discarding a value.
#[inline]
pub fn black_box<T>(x: T) -> T {
    std::hint::black_box(x)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_measures_something() {
        let r = bench_n("noop-ish", 100, || {
            black_box((0..100).sum::<u64>());
        });
        assert!(r.ns_per_iter > 0.0);
        assert_eq!(r.iters, 100);
    }
}
