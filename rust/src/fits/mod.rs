//! FITS-lite: a compact, self-describing binary container for field
//! images — the stand-in for the SDSS FITS frame files (§IV).
//!
//! One file per (field, band), as in SDSS ("each field has images of it
//! stored in five different files, one per filter band"). Layout:
//!
//! ```text
//! magic  "CFTS"            4 bytes
//! version u32              little-endian (all integers are LE)
//! header  u32 count, then count x (key: len-prefixed utf8, value: f64)
//! pixels  u64 count, then count x f32
//! ```

use std::fs::File;
use std::io::{self, BufReader, BufWriter, Read, Write};
use std::path::{Path, PathBuf};

use crate::imaging::render::BandImage;
use crate::imaging::survey::FieldGeom;
use crate::imaging::FieldImages;
use crate::model::render::PixelRect;
use crate::model::PsfBand;

const MAGIC: &[u8; 4] = b"CFTS";
const VERSION: u32 = 1;

/// A parsed FITS-lite file: numeric header plus pixel payload.
#[derive(Clone, Debug, Default)]
pub struct FitsLite {
    pub header: Vec<(String, f64)>,
    pub pixels: Vec<f32>,
}

impl FitsLite {
    pub fn get(&self, key: &str) -> Option<f64> {
        self.header.iter().find(|(k, _)| k == key).map(|&(_, v)| v)
    }

    pub fn require(&self, key: &str) -> io::Result<f64> {
        self.get(key).ok_or_else(|| {
            io::Error::new(io::ErrorKind::InvalidData, format!("missing header key {key}"))
        })
    }

    pub fn set(&mut self, key: &str, v: f64) {
        self.header.push((key.to_string(), v));
    }
}

pub fn write_fits(path: &Path, f: &FitsLite) -> io::Result<()> {
    let mut w = BufWriter::new(File::create(path)?);
    w.write_all(MAGIC)?;
    w.write_all(&VERSION.to_le_bytes())?;
    w.write_all(&(f.header.len() as u32).to_le_bytes())?;
    for (k, v) in &f.header {
        let kb = k.as_bytes();
        w.write_all(&(kb.len() as u32).to_le_bytes())?;
        w.write_all(kb)?;
        w.write_all(&v.to_le_bytes())?;
    }
    w.write_all(&(f.pixels.len() as u64).to_le_bytes())?;
    for px in &f.pixels {
        w.write_all(&px.to_le_bytes())?;
    }
    w.flush()
}

pub fn read_fits(path: &Path) -> io::Result<FitsLite> {
    let mut r = BufReader::new(File::open(path)?);
    let mut magic = [0u8; 4];
    r.read_exact(&mut magic)?;
    if &magic != MAGIC {
        return Err(io::Error::new(io::ErrorKind::InvalidData, "bad magic"));
    }
    let mut b4 = [0u8; 4];
    r.read_exact(&mut b4)?;
    let version = u32::from_le_bytes(b4);
    if version != VERSION {
        return Err(io::Error::new(
            io::ErrorKind::InvalidData,
            format!("unsupported version {version}"),
        ));
    }
    r.read_exact(&mut b4)?;
    let nh = u32::from_le_bytes(b4) as usize;
    if nh > 10_000 {
        return Err(io::Error::new(io::ErrorKind::InvalidData, "absurd header count"));
    }
    let mut header = Vec::with_capacity(nh);
    for _ in 0..nh {
        r.read_exact(&mut b4)?;
        let klen = u32::from_le_bytes(b4) as usize;
        if klen > 4096 {
            return Err(io::Error::new(io::ErrorKind::InvalidData, "absurd key length"));
        }
        let mut kb = vec![0u8; klen];
        r.read_exact(&mut kb)?;
        let key = String::from_utf8(kb)
            .map_err(|e| io::Error::new(io::ErrorKind::InvalidData, e))?;
        let mut b8 = [0u8; 8];
        r.read_exact(&mut b8)?;
        header.push((key, f64::from_le_bytes(b8)));
    }
    let mut b8 = [0u8; 8];
    r.read_exact(&mut b8)?;
    let np = u64::from_le_bytes(b8) as usize;
    let mut pixels = vec![0f32; np];
    let mut buf = vec![0u8; np * 4];
    r.read_exact(&mut buf)?;
    for (i, px) in pixels.iter_mut().enumerate() {
        *px = f32::from_le_bytes(buf[i * 4..i * 4 + 4].try_into().unwrap());
    }
    Ok(FitsLite { header, pixels })
}

/// Standard filename for a (field, band) file.
pub fn band_filename(field_id: usize, band: usize) -> String {
    format!("field-{field_id:06}-band-{band}.cfits")
}

/// Serialize one band of a field (geometry + observing metadata + pixels).
pub fn band_to_fits(img: &BandImage, geom: &FieldGeom) -> FitsLite {
    let mut f = FitsLite { header: vec![], pixels: img.pixels.clone() };
    f.set("FIELD", geom.id as f64);
    f.set("EPOCH", geom.epoch as f64);
    f.set("BAND", img.band as f64);
    f.set("X0", img.rect.x0);
    f.set("Y0", img.rect.y0);
    f.set("ROWS", img.rect.rows as f64);
    f.set("COLS", img.rect.cols as f64);
    f.set("GAIN", geom.gain[img.band]);
    f.set("SKY", geom.sky[img.band]);
    for (k, c) in geom.psf[img.band].iter().enumerate() {
        for (p, v) in c.iter().enumerate() {
            f.set(&format!("PSF{k}{p}"), *v);
        }
    }
    f
}

/// Write a whole field (five files) into `dir`.
pub fn write_field(dir: &Path, field: &FieldImages) -> io::Result<Vec<PathBuf>> {
    std::fs::create_dir_all(dir)?;
    let mut paths = Vec::new();
    for band in &field.bands {
        let path = dir.join(band_filename(field.field_id, band.band));
        write_fits(&path, &band_to_fits(band, &field.geom))?;
        paths.push(path);
    }
    Ok(paths)
}

/// Read a whole field back (requires all five band files).
pub fn read_field(dir: &Path, field_id: usize) -> io::Result<FieldImages> {
    let mut bands = Vec::with_capacity(5);
    let mut geom: Option<FieldGeom> = None;
    for band in 0..5 {
        let f = read_fits(&dir.join(band_filename(field_id, band)))?;
        let rect = PixelRect {
            x0: f.require("X0")?,
            y0: f.require("Y0")?,
            rows: f.require("ROWS")? as usize,
            cols: f.require("COLS")? as usize,
        };
        let g = geom.get_or_insert_with(|| FieldGeom {
            id: field_id,
            epoch: 0,
            rect,
            psf: [[[0.0; 6]; 2]; 5],
            gain: [0.0; 5],
            sky: [0.0; 5],
        });
        g.epoch = f.require("EPOCH")? as usize;
        g.gain[band] = f.require("GAIN")?;
        g.sky[band] = f.require("SKY")?;
        let mut psf: PsfBand = [[0.0; 6]; 2];
        for (k, c) in psf.iter_mut().enumerate() {
            for (p, v) in c.iter_mut().enumerate() {
                *v = f.require(&format!("PSF{k}{p}"))?;
            }
        }
        g.psf[band] = psf;
        if f.pixels.len() != rect.len() {
            return Err(io::Error::new(io::ErrorKind::InvalidData, "pixel count mismatch"));
        }
        bands.push(BandImage { band, rect, pixels: f.pixels });
    }
    let geom = geom.unwrap();
    Ok(FieldImages { field_id, epoch: geom.epoch, geom, bands })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::imaging::render::render_field;
    use crate::imaging::survey::{Survey, SurveyConfig};
    use crate::prng::Rng;

    fn tmpdir(tag: &str) -> PathBuf {
        let d = std::env::temp_dir().join(format!("celeste-fits-test-{tag}-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&d);
        std::fs::create_dir_all(&d).unwrap();
        d
    }

    #[test]
    fn roundtrip_raw() {
        let d = tmpdir("raw");
        let mut f = FitsLite { header: vec![], pixels: vec![1.5, -2.0, 3.25] };
        f.set("A", 1.0);
        f.set("LONG_KEY_NAME", -7.5);
        let p = d.join("x.cfits");
        write_fits(&p, &f).unwrap();
        let g = read_fits(&p).unwrap();
        assert_eq!(g.pixels, f.pixels);
        assert_eq!(g.get("A"), Some(1.0));
        assert_eq!(g.get("LONG_KEY_NAME"), Some(-7.5));
        assert_eq!(g.get("MISSING"), None);
        std::fs::remove_dir_all(&d).unwrap();
    }

    #[test]
    fn rejects_corrupt_magic() {
        let d = tmpdir("magic");
        let p = d.join("bad.cfits");
        std::fs::write(&p, b"NOPE....").unwrap();
        assert!(read_fits(&p).is_err());
        std::fs::remove_dir_all(&d).unwrap();
    }

    #[test]
    fn rejects_truncated() {
        let d = tmpdir("trunc");
        let mut f = FitsLite { header: vec![], pixels: vec![0.0; 100] };
        f.set("X", 1.0);
        let p = d.join("t.cfits");
        write_fits(&p, &f).unwrap();
        let bytes = std::fs::read(&p).unwrap();
        std::fs::write(&p, &bytes[..bytes.len() - 10]).unwrap();
        assert!(read_fits(&p).is_err());
        std::fs::remove_dir_all(&d).unwrap();
    }

    #[test]
    fn field_roundtrip() {
        let survey = Survey::layout(SurveyConfig {
            sky_width: 96.0,
            sky_height: 96.0,
            field_w: 96,
            field_h: 96,
            n_epochs: 1,
            ..Default::default()
        });
        let mut rng = Rng::new(1);
        let field = render_field(&[], &survey.fields[0], &mut rng);
        let d = tmpdir("field");
        write_field(&d, &field).unwrap();
        let back = read_field(&d, field.field_id).unwrap();
        assert_eq!(back.field_id, field.field_id);
        assert_eq!(back.geom.rect, field.geom.rect);
        for b in 0..5 {
            assert_eq!(back.bands[b].pixels, field.bands[b].pixels);
            assert_eq!(back.geom.psf[b], field.geom.psf[b]);
            assert!((back.geom.sky[b] - field.geom.sky[b]).abs() < 1e-12);
        }
        std::fs::remove_dir_all(&d).unwrap();
    }
}
