//! Field rendering and Poisson observation: the forward model at survey
//! scale, used to synthesize datasets (and by the coordinator to render
//! fixed neighbors into patch backgrounds).

use crate::model::render::PixelRect;
use crate::model::{galaxy_comps, star_comps, SourceParams};
use crate::prng::Rng;

use super::survey::FieldGeom;

/// One band of one field: expected rate and observed counts.
#[derive(Clone, Debug)]
pub struct BandImage {
    pub band: usize,
    pub rect: PixelRect,
    /// observed Poisson counts
    pub pixels: Vec<f32>,
}

impl BandImage {
    /// Value at global pixel (x, y); None if outside.
    pub fn at_global(&self, x: f64, y: f64) -> Option<f32> {
        let c = (x - self.rect.x0).floor();
        let r = (y - self.rect.y0).floor();
        if c < 0.0 || r < 0.0 || c >= self.rect.cols as f64 || r >= self.rect.rows as f64 {
            return None;
        }
        Some(self.pixels[r as usize * self.rect.cols + c as usize])
    }
}

/// All five bands of one field exposure.
#[derive(Clone, Debug)]
pub struct FieldImages {
    pub field_id: usize,
    pub epoch: usize,
    pub geom: FieldGeom,
    pub bands: Vec<BandImage>,
}

impl FieldImages {
    /// Total bytes of pixel payload (for the global-array store model).
    pub fn nbytes(&self) -> usize {
        self.bands.iter().map(|b| b.pixels.len() * 4).sum()
    }
}

/// Extra rect margin when deciding which sources contribute to a field —
/// bright wings can reach in from outside.
const SOURCE_MARGIN: f64 = 24.0;

/// Accumulate the expected rate image of one band (sky + all sources).
pub fn expected_rate_band(
    sources: &[SourceParams],
    geom: &FieldGeom,
    band: usize,
) -> Vec<f64> {
    let rect = geom.rect;
    let mut rate = vec![geom.sky[band]; rect.len()];
    for s in sources {
        if s.pos.0 < rect.x0 - SOURCE_MARGIN
            || s.pos.0 > rect.x0 + rect.cols as f64 + SOURCE_MARGIN
            || s.pos.1 < rect.y0 - SOURCE_MARGIN
            || s.pos.1 > rect.y0 + rect.rows as f64 + SOURCE_MARGIN
        {
            continue;
        }
        accumulate_source(&mut rate, &rect, s, geom, band, 1.0);
    }
    rate
}

/// Add `scale * gain * flux_b * profile` of one source into `buf` over `rect`.
pub fn accumulate_source(
    buf: &mut [f64],
    rect: &PixelRect,
    s: &SourceParams,
    geom: &FieldGeom,
    band: usize,
    scale: f64,
) {
    let amp = scale * geom.gain[band] * s.flux_in_band(band);
    if s.is_galaxy {
        let comps = galaxy_comps(s.pos, &geom.psf[band], &s.shape);
        crate::model::accumulate_mixture(buf, rect, &comps, amp);
    } else {
        let comps = star_comps(s.pos, &geom.psf[band]);
        crate::model::accumulate_mixture(buf, rect, &comps, amp);
    }
}

/// Render one field exposure: expected rates then Poisson observation.
pub fn render_field(sources: &[SourceParams], geom: &FieldGeom, rng: &mut Rng) -> FieldImages {
    let mut bands = Vec::with_capacity(5);
    for band in 0..5 {
        let rate = expected_rate_band(sources, geom, band);
        let pixels: Vec<f32> = rate.iter().map(|&r| rng.poisson(r) as f32).collect();
        bands.push(BandImage { band, rect: geom.rect, pixels });
    }
    FieldImages { field_id: geom.id, epoch: geom.epoch, geom: geom.clone(), bands }
}

/// Render a field with saturation: pixels above `limit` are clipped (and
/// NOT flagged) — reproduces the systematic the paper blames for Photo's
/// brightness advantage in Table I (§VII).
pub fn render_field_saturating(
    sources: &[SourceParams],
    geom: &FieldGeom,
    rng: &mut Rng,
    limit: f64,
) -> FieldImages {
    let mut f = render_field(sources, geom, rng);
    for b in &mut f.bands {
        for p in &mut b.pixels {
            if *p as f64 > limit {
                *p = limit as f32;
            }
        }
    }
    f
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::imaging::survey::{Survey, SurveyConfig};
    use crate::model::GalaxyShape;

    fn tiny_survey() -> Survey {
        Survey::layout(SurveyConfig {
            sky_width: 128.0,
            sky_height: 128.0,
            field_w: 128,
            field_h: 128,
            n_epochs: 1,
            jitter: 0.0,
            ..Default::default()
        })
    }

    fn star_at(x: f64, y: f64, flux: f64) -> SourceParams {
        SourceParams {
            pos: (x, y),
            is_galaxy: false,
            flux_r: flux,
            colors: [0.0; 4],
            shape: GalaxyShape::point_like(),
        }
    }

    #[test]
    fn rate_includes_sky_everywhere() {
        let survey = tiny_survey();
        let geom = &survey.fields[0];
        let rate = expected_rate_band(&[], geom, 2);
        for &r in &rate {
            assert!((r - geom.sky[2]).abs() < 1e-12);
        }
    }

    #[test]
    fn source_flux_lands_in_rate() {
        let survey = tiny_survey();
        let geom = &survey.fields[0];
        let s = star_at(64.0, 64.0, 500.0);
        let rate = expected_rate_band(&[s], geom, 2);
        let total: f64 = rate.iter().sum();
        let sky_total = geom.sky[2] * 128.0 * 128.0;
        let excess = total - sky_total;
        let want = geom.gain[2] * 500.0;
        assert!((excess - want).abs() / want < 0.01, "excess {excess} want {want}");
    }

    #[test]
    fn poisson_observation_near_rate() {
        let survey = tiny_survey();
        let geom = &survey.fields[0];
        let s = star_at(64.0, 64.0, 2000.0);
        let mut rng = Rng::new(5);
        let f = render_field(&[s.clone()], geom, &mut rng);
        let rate = expected_rate_band(&[s], geom, 2);
        let obs: f64 = f.bands[2].pixels.iter().map(|&p| p as f64).sum();
        let exp: f64 = rate.iter().sum();
        assert!((obs - exp).abs() / exp < 0.01, "obs {obs} exp {exp}");
    }

    #[test]
    fn saturation_clips() {
        let survey = tiny_survey();
        let geom = &survey.fields[0];
        let s = star_at(64.0, 64.0, 5e6);
        let mut rng = Rng::new(6);
        let f = render_field_saturating(&[s], geom, &mut rng, 10_000.0);
        let max = f.bands[2].pixels.iter().cloned().fold(0.0f32, f32::max);
        assert!(max <= 10_000.0);
    }

    #[test]
    fn at_global_indexing() {
        let survey = tiny_survey();
        let geom = &survey.fields[0];
        let mut rng = Rng::new(7);
        let f = render_field(&[], geom, &mut rng);
        let b = &f.bands[0];
        assert!(b.at_global(0.5, 0.5).is_some());
        assert!(b.at_global(-1.0, 0.5).is_none());
        assert!(b.at_global(0.5, 500.0).is_none());
        assert_eq!(b.at_global(0.5, 0.5).unwrap(), b.pixels[0]);
    }
}
