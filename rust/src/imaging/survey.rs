//! Survey geometry: how fields tile (and overlap) the sky.
//!
//! SDSS images the sky in "fields" along drift-scan stripes; adjacent
//! fields overlap, and separate runs re-image the same region (paper
//! Fig 1). We reproduce that: a jittered grid of fields with configurable
//! overlap, and `n_epochs` independent passes (each with its own seeing),
//! so one light source generally appears in several images.

use crate::model::render::PixelRect;
use crate::model::PsfBand;
use crate::prng::Rng;

#[derive(Clone, Debug)]
pub struct SurveyConfig {
    /// sky extent, pixels
    pub sky_width: f64,
    pub sky_height: f64,
    /// field size, pixels (paper: 2048 x 1361; scaled down for tests)
    pub field_w: usize,
    pub field_h: usize,
    /// fraction of a field shared with each neighbor (0..0.5)
    pub overlap: f64,
    /// number of complete imaging passes over the sky
    pub n_epochs: usize,
    /// random jitter of field origins, pixels
    pub jitter: f64,
    /// mean sky background per band (counts/pixel)
    pub sky_level: [f64; 5],
    /// per-band gain (counts per flux unit)
    pub gain: [f64; 5],
    /// seeing: PSF core width varies per field uniformly in this range
    pub seeing: (f64, f64),
    pub seed: u64,
}

impl Default for SurveyConfig {
    fn default() -> Self {
        SurveyConfig {
            sky_width: 2048.0,
            sky_height: 1361.0,
            field_w: 512,
            field_h: 341,
            overlap: 0.12,
            n_epochs: 1,
            jitter: 8.0,
            sky_level: [30.0, 60.0, 80.0, 70.0, 40.0],
            gain: [0.6, 1.0, 1.0, 0.9, 0.7],
            seeing: (0.9, 1.6),
            seed: 7,
        }
    }
}

/// Geometry + per-band observing conditions of one field exposure.
#[derive(Clone, Debug)]
pub struct FieldGeom {
    pub id: usize,
    pub epoch: usize,
    pub rect: PixelRect,
    pub psf: [PsfBand; 5],
    pub gain: [f64; 5],
    pub sky: [f64; 5],
}

/// A fully-laid-out survey.
#[derive(Clone, Debug)]
pub struct Survey {
    pub config: SurveyConfig,
    pub fields: Vec<FieldGeom>,
}

/// A plausible 2-component PSF for a given per-band seeing width.
pub fn make_psf(width: f64, rng: &mut Rng) -> PsfBand {
    let w2 = width * width;
    let e = 0.1 * w2 * (rng.uniform() - 0.5); // slight ellipticity
    [
        [0.8, 0.0, 0.0, w2, e, w2 * (1.0 + 0.08 * (rng.uniform() - 0.5))],
        [
            0.2,
            0.15 * (rng.uniform() - 0.5),
            0.15 * (rng.uniform() - 0.5),
            2.8 * w2,
            -e,
            2.8 * w2,
        ],
    ]
}

impl Survey {
    /// Lay out the survey: for each epoch, a jittered overlapping grid.
    pub fn layout(config: SurveyConfig) -> Survey {
        let mut rng = Rng::new(config.seed);
        let mut fields = Vec::new();
        let step_x = config.field_w as f64 * (1.0 - config.overlap);
        let step_y = config.field_h as f64 * (1.0 - config.overlap);
        let nx = (config.sky_width / step_x).ceil().max(1.0) as usize;
        let ny = (config.sky_height / step_y).ceil().max(1.0) as usize;
        let mut id = 0;
        for epoch in 0..config.n_epochs {
            for iy in 0..ny {
                for ix in 0..nx {
                    let jx = rng.uniform_in(-config.jitter, config.jitter);
                    let jy = rng.uniform_in(-config.jitter, config.jitter);
                    let x0 = (ix as f64 * step_x + jx)
                        .clamp(0.0, (config.sky_width - config.field_w as f64).max(0.0));
                    let y0 = (iy as f64 * step_y + jy)
                        .clamp(0.0, (config.sky_height - config.field_h as f64).max(0.0));
                    let rect = PixelRect {
                        x0: x0.round(),
                        y0: y0.round(),
                        rows: config.field_h,
                        cols: config.field_w,
                    };
                    let mut psf = [[[0.0; 6]; 2]; 5];
                    let mut sky = [0.0; 5];
                    let base_seeing = rng.uniform_in(config.seeing.0, config.seeing.1);
                    for b in 0..5 {
                        // band-dependent seeing, as in conftest.default_psf
                        psf[b] = make_psf(base_seeing * (1.0 + 0.1 * b as f64), &mut rng);
                        sky[b] = config.sky_level[b] * rng.uniform_in(0.85, 1.15);
                    }
                    fields.push(FieldGeom {
                        id,
                        epoch,
                        rect,
                        psf,
                        gain: config.gain,
                        sky,
                    });
                    id += 1;
                }
            }
        }
        Survey { config, fields }
    }

    /// All fields whose pixel rect contains the global position (with a
    /// margin so patches stay inside).
    pub fn fields_containing(&self, pos: (f64, f64), margin: f64) -> Vec<&FieldGeom> {
        self.fields
            .iter()
            .filter(|f| {
                pos.0 >= f.rect.x0 + margin
                    && pos.0 < f.rect.x0 + f.rect.cols as f64 - margin
                    && pos.1 >= f.rect.y0 + margin
                    && pos.1 < f.rect.y0 + f.rect.rows as f64 - margin
            })
            .collect()
    }

    /// Count of (unordered) overlapping same-epoch field pairs — the Fig 1
    /// statistic.
    pub fn overlap_pairs(&self) -> usize {
        let mut n = 0;
        for (i, a) in self.fields.iter().enumerate() {
            for b in &self.fields[i + 1..] {
                if a.epoch == b.epoch && a.rect.intersect(&b.rect).is_some() {
                    n += 1;
                }
            }
        }
        n
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn small() -> SurveyConfig {
        SurveyConfig {
            sky_width: 600.0,
            sky_height: 400.0,
            field_w: 256,
            field_h: 192,
            n_epochs: 2,
            ..Default::default()
        }
    }

    #[test]
    fn covers_sky() {
        let s = Survey::layout(small());
        assert!(!s.fields.is_empty());
        // every interior point is inside at least one epoch-0 field
        let mut rng = Rng::new(1);
        for _ in 0..200 {
            let p = (rng.uniform_in(5.0, 595.0), rng.uniform_in(5.0, 395.0));
            let hit = s
                .fields
                .iter()
                .filter(|f| f.epoch == 0)
                .any(|f| {
                    p.0 >= f.rect.x0
                        && p.0 < f.rect.x0 + f.rect.cols as f64
                        && p.1 >= f.rect.y0
                        && p.1 < f.rect.y0 + f.rect.rows as f64
                });
            assert!(hit, "uncovered {p:?}");
        }
    }

    #[test]
    fn epochs_multiply_fields() {
        let one = Survey::layout(SurveyConfig { n_epochs: 1, ..small() });
        let two = Survey::layout(SurveyConfig { n_epochs: 2, ..small() });
        assert_eq!(two.fields.len(), 2 * one.fields.len());
    }

    #[test]
    fn fields_overlap() {
        let s = Survey::layout(small());
        assert!(s.overlap_pairs() > 0, "survey must have overlapping fields (Fig 1)");
    }

    #[test]
    fn multiple_epochs_see_same_source() {
        let s = Survey::layout(small());
        let hits = s.fields_containing((300.0, 200.0), 16.0);
        assert!(hits.len() >= 2, "a central point should be imaged in >= 2 fields");
    }

    #[test]
    fn psf_weights_normalized() {
        let s = Survey::layout(small());
        for f in &s.fields {
            for b in 0..5 {
                let total: f64 = f.psf[b].iter().map(|c| c[0]).sum();
                assert!((total - 1.0).abs() < 1e-12);
            }
        }
    }
}
