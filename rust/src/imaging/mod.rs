//! The imaging substrate: survey geometry, field rendering, Poisson
//! observation, and patch extraction — the synthetic twin of the SDSS
//! field/"frame" pipeline the paper consumes (§IV).

pub mod patch;
pub mod render;
pub mod survey;

pub use patch::{extract_patch, Patch};
pub use render::{render_field, render_field_saturating, BandImage, FieldImages};
pub use survey::{FieldGeom, Survey, SurveyConfig};
