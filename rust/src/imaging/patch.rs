//! Patch extraction: the fixed-shape (5 x 32 x 32) inputs the AOT
//! artifacts consume, cut around a source from one field exposure.

use crate::model::layout as L;
use crate::model::render::PixelRect;
use crate::model::{galaxy_comps, star_comps, SourceParams};

use super::render::FieldImages;
use super::survey::FieldGeom;

/// One epoch's worth of artifact inputs for one source.
#[derive(Clone, Debug)]
pub struct Patch {
    /// patch rect in global coordinates (PATCH x PATCH)
    pub rect: PixelRect,
    /// observed counts, [band][row*PATCH+col]
    pub pixels: Vec<f64>,
    /// background rate: sky + fixed neighbors, same layout
    pub bg: Vec<f64>,
    /// 1.0 where the pixel exists in the field, else 0.0
    pub mask: Vec<f64>,
    /// per-band PSF, flattened [band][comp][param]
    pub psf: Vec<f64>,
    /// per-band gain
    pub gain: Vec<f64>,
    /// fraction of valid pixels
    pub coverage: f64,
}

const P: usize = L::PATCH;
const B: usize = L::N_BANDS;

/// Cut a PATCH x PATCH x bands patch centered at `center` out of `field`.
///
/// `neighbors` are rendered into the background at their current catalog
/// estimates (the paper's decoupling: neighbors stay fixed while this
/// source is optimized). Returns None if the patch misses the field.
pub fn extract_patch(
    field: &FieldImages,
    center: (f64, f64),
    neighbors: &[SourceParams],
) -> Option<Patch> {
    // integer patch origin so pixel centers align with the field grid
    let x0 = (center.0 - P as f64 / 2.0).round();
    let y0 = (center.1 - P as f64 / 2.0).round();
    let rect = PixelRect { x0, y0, rows: P, cols: P };
    let frect = field.geom.rect;
    rect.intersect(&frect)?;

    let mut pixels = vec![0f64; B * P * P];
    let mut bg = vec![0f64; B * P * P];
    let mut mask = vec![0f64; B * P * P];
    let mut psf = vec![0f64; B * L::K_PSF * L::PSF_PARAMS];
    let mut gain = vec![0f64; B];

    let mut valid = 0usize;
    for b in 0..B {
        let img = &field.bands[b];
        // neighbor background: sky + fixed neighbor mixtures, f64 then cast
        let mut nb = vec![field.geom.sky[b]; P * P];
        for n in neighbors {
            super::render::accumulate_source(&mut nb, &rect, n, &field.geom, b, 1.0);
        }
        for r in 0..P {
            let gy = y0 + r as f64 + 0.5;
            for c in 0..P {
                let gx = x0 + c as f64 + 0.5;
                let idx = b * P * P + r * P + c;
                if let Some(v) = img.at_global(gx, gy) {
                    pixels[idx] = v as f64;
                    mask[idx] = 1.0;
                    if b == 0 {
                        valid += 1;
                    }
                }
                bg[idx] = nb[r * P + c];
            }
        }
        for k in 0..L::K_PSF {
            for p in 0..L::PSF_PARAMS {
                psf[(b * L::K_PSF + k) * L::PSF_PARAMS + p] =
                    field.geom.psf[b][k][p];
            }
        }
        gain[b] = field.geom.gain[b];
    }

    Some(Patch {
        rect,
        pixels,
        bg,
        mask,
        psf,
        gain,
        coverage: valid as f64 / (P * P) as f64,
    })
}

/// Expected *own-source* rate over a patch (no sky, no neighbors) — used
/// by tests and by the Photo baseline's model-image subtraction.
pub fn own_rate(patch_rect: &PixelRect, s: &SourceParams, geom: &FieldGeom, band: usize) -> Vec<f64> {
    let mut buf = vec![0.0; patch_rect.len()];
    let amp = geom.gain[band] * s.flux_in_band(band);
    if s.is_galaxy {
        let comps = galaxy_comps(s.pos, &geom.psf[band], &s.shape);
        crate::model::accumulate_mixture(&mut buf, patch_rect, &comps, amp);
    } else {
        let comps = star_comps(s.pos, &geom.psf[band]);
        crate::model::accumulate_mixture(&mut buf, patch_rect, &comps, amp);
    }
    buf
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::imaging::render::render_field;
    use crate::imaging::survey::{Survey, SurveyConfig};
    use crate::model::GalaxyShape;
    use crate::prng::Rng;

    fn setup() -> (Survey, FieldImages, SourceParams) {
        let survey = Survey::layout(SurveyConfig {
            sky_width: 256.0,
            sky_height: 256.0,
            field_w: 256,
            field_h: 256,
            n_epochs: 1,
            jitter: 0.0,
            ..Default::default()
        });
        let s = SourceParams {
            pos: (128.0, 128.0),
            is_galaxy: false,
            flux_r: 20_000.0,
            colors: [0.1; 4],
            shape: GalaxyShape::point_like(),
        };
        let mut rng = Rng::new(3);
        let f = render_field(std::slice::from_ref(&s), &survey.fields[0], &mut rng);
        (survey, f, s)
    }

    #[test]
    fn interior_patch_fully_covered() {
        let (_s, f, src) = setup();
        let p = extract_patch(&f, src.pos, &[]).unwrap();
        assert_eq!(p.coverage, 1.0);
        assert!(p.mask.iter().all(|&m| m == 1.0));
        assert_eq!(p.pixels.len(), 5 * 32 * 32);
    }

    #[test]
    fn boundary_patch_partially_masked() {
        let (_s, f, _) = setup();
        let p = extract_patch(&f, (4.0, 128.0), &[]).unwrap();
        assert!(p.coverage > 0.0 && p.coverage < 1.0, "coverage {}", p.coverage);
        // masked pixels must be zero-filled
        for (px, m) in p.pixels.iter().zip(&p.mask) {
            if *m == 0.0 {
                assert_eq!(*px, 0.0);
            }
        }
    }

    #[test]
    fn far_patch_is_none() {
        let (_s, f, _) = setup();
        assert!(extract_patch(&f, (10_000.0, 10_000.0), &[]).is_none());
    }

    #[test]
    fn neighbor_raises_background() {
        let (_s, f, src) = setup();
        let neighbor = SourceParams {
            pos: (124.0, 128.0),
            is_galaxy: false,
            flux_r: 500.0,
            colors: [0.0; 4],
            shape: GalaxyShape::point_like(),
        };
        let p0 = extract_patch(&f, src.pos, &[]).unwrap();
        let p1 = extract_patch(&f, src.pos, &[neighbor]).unwrap();
        let b0: f64 = p0.bg.iter().map(|&x| x as f64).sum();
        let b1: f64 = p1.bg.iter().map(|&x| x as f64).sum();
        assert!(b1 > b0 + 100.0, "neighbor must contribute to bg: {b0} vs {b1}");
    }

    #[test]
    fn patch_contains_source_flux() {
        let (_s, f, src) = setup();
        let p = extract_patch(&f, src.pos, &[]).unwrap();
        // band 2: sum(pixels - bg) ~ gain * flux
        let b = 2;
        let mut excess = 0.0;
        for i in 0..(32 * 32) {
            let idx = b * 32 * 32 + i;
            excess += (p.pixels[idx] - p.bg[idx]) as f64;
        }
        let want = f.geom.gain[b] * src.flux_r;
        assert!((excess - want).abs() / want < 0.15, "excess {excess} want {want}");
    }

    #[test]
    fn psf_gain_passthrough() {
        let (_s, f, src) = setup();
        let p = extract_patch(&f, src.pos, &[]).unwrap();
        assert_eq!(p.gain[2], f.geom.gain[2]);
        assert_eq!(p.psf[0], f.geom.psf[0][0][0]);
    }
}
