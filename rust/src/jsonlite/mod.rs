//! Minimal JSON parser (the offline registry has no serde) — used for
//! `artifacts/manifest.json` and experiment result files.

use std::collections::BTreeMap;
use std::fmt;

#[derive(Clone, Debug, PartialEq)]
pub enum Value {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Value>),
    Obj(BTreeMap<String, Value>),
}

impl Value {
    pub fn get(&self, key: &str) -> Option<&Value> {
        match self {
            Value::Obj(m) => m.get(key),
            _ => None,
        }
    }

    pub fn idx(&self, i: usize) -> Option<&Value> {
        match self {
            Value::Arr(v) => v.get(i),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Value::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|f| f as usize)
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Value::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Value::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Value]> {
        match self {
            Value::Arr(v) => Some(v),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Value>> {
        match self {
            Value::Obj(m) => Some(m),
            _ => None,
        }
    }
}

#[derive(Debug)]
pub struct ParseError {
    pub pos: usize,
    pub msg: String,
}

impl fmt::Display for ParseError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json parse error at byte {}: {}", self.pos, self.msg)
    }
}

impl std::error::Error for ParseError {}

pub fn parse(s: &str) -> Result<Value, ParseError> {
    let mut p = Parser { b: s.as_bytes(), pos: 0 };
    p.ws();
    let v = p.value()?;
    p.ws();
    if p.pos != p.b.len() {
        return Err(p.err("trailing data"));
    }
    Ok(v)
}

struct Parser<'a> {
    b: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> ParseError {
        ParseError { pos: self.pos, msg: msg.to_string() }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.pos).copied()
    }

    fn bump(&mut self) -> Option<u8> {
        let c = self.peek();
        if c.is_some() {
            self.pos += 1;
        }
        c
    }

    fn ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, c: u8) -> Result<(), ParseError> {
        if self.bump() == Some(c) {
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", c as char)))
        }
    }

    fn lit(&mut self, word: &str, v: Value) -> Result<Value, ParseError> {
        if self.b[self.pos..].starts_with(word.as_bytes()) {
            self.pos += word.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected {word}")))
        }
    }

    fn value(&mut self) -> Result<Value, ParseError> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Value::Str(self.string()?)),
            Some(b't') => self.lit("true", Value::Bool(true)),
            Some(b'f') => self.lit("false", Value::Bool(false)),
            Some(b'n') => self.lit("null", Value::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("unexpected character")),
        }
    }

    fn object(&mut self) -> Result<Value, ParseError> {
        self.expect(b'{')?;
        let mut m = BTreeMap::new();
        self.ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Value::Obj(m));
        }
        loop {
            self.ws();
            let k = self.string()?;
            self.ws();
            self.expect(b':')?;
            self.ws();
            let v = self.value()?;
            m.insert(k, v);
            self.ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b'}') => return Ok(Value::Obj(m)),
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }

    fn array(&mut self) -> Result<Value, ParseError> {
        self.expect(b'[')?;
        let mut v = Vec::new();
        self.ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Value::Arr(v));
        }
        loop {
            self.ws();
            v.push(self.value()?);
            self.ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b']') => return Ok(Value::Arr(v)),
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn string(&mut self) -> Result<String, ParseError> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.bump() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => return Ok(out),
                Some(b'\\') => match self.bump() {
                    Some(b'"') => out.push('"'),
                    Some(b'\\') => out.push('\\'),
                    Some(b'/') => out.push('/'),
                    Some(b'b') => out.push('\u{8}'),
                    Some(b'f') => out.push('\u{c}'),
                    Some(b'n') => out.push('\n'),
                    Some(b'r') => out.push('\r'),
                    Some(b't') => out.push('\t'),
                    Some(b'u') => {
                        let mut code = 0u32;
                        for _ in 0..4 {
                            let c = self.bump().ok_or_else(|| self.err("bad \\u"))?;
                            code = code * 16
                                + (c as char).to_digit(16).ok_or_else(|| self.err("bad hex"))?;
                        }
                        out.push(char::from_u32(code).unwrap_or('\u{fffd}'));
                    }
                    _ => return Err(self.err("bad escape")),
                },
                Some(c) if c < 0x80 => out.push(c as char),
                Some(c) => {
                    // re-assemble UTF-8 multibyte
                    let len = if c >= 0xF0 { 4 } else if c >= 0xE0 { 3 } else { 2 };
                    let start = self.pos - 1;
                    for _ in 1..len {
                        self.bump();
                    }
                    let s = std::str::from_utf8(&self.b[start..self.pos])
                        .map_err(|_| self.err("bad utf8"))?;
                    out.push_str(s);
                }
            }
        }
    }

    fn number(&mut self) -> Result<Value, ParseError> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit() || matches!(c, b'.' | b'e' | b'E' | b'+' | b'-'))
        {
            self.pos += 1;
        }
        let s = std::str::from_utf8(&self.b[start..self.pos]).unwrap();
        s.parse::<f64>()
            .map(Value::Num)
            .map_err(|_| self.err("bad number"))
    }
}

/// Serialize a `Value` back to compact JSON (for experiment outputs).
pub fn to_string(v: &Value) -> String {
    let mut s = String::new();
    write_value(v, &mut s);
    s
}

fn write_value(v: &Value, out: &mut String) {
    match v {
        Value::Null => out.push_str("null"),
        Value::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
        Value::Num(n) => {
            if n.fract() == 0.0 && n.abs() < 1e15 {
                out.push_str(&format!("{}", *n as i64));
            } else {
                out.push_str(&format!("{n}"));
            }
        }
        Value::Str(s) => {
            out.push('"');
            for c in s.chars() {
                match c {
                    '"' => out.push_str("\\\""),
                    '\\' => out.push_str("\\\\"),
                    '\n' => out.push_str("\\n"),
                    '\t' => out.push_str("\\t"),
                    '\r' => out.push_str("\\r"),
                    c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
                    c => out.push(c),
                }
            }
            out.push('"');
        }
        Value::Arr(a) => {
            out.push('[');
            for (i, x) in a.iter().enumerate() {
                if i > 0 {
                    out.push(',');
                }
                write_value(x, out);
            }
            out.push(']');
        }
        Value::Obj(m) => {
            out.push('{');
            for (i, (k, x)) in m.iter().enumerate() {
                if i > 0 {
                    out.push(',');
                }
                write_value(&Value::Str(k.clone()), out);
                out.push(':');
                write_value(x, out);
            }
            out.push('}');
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_scalars() {
        assert_eq!(parse("null").unwrap(), Value::Null);
        assert_eq!(parse("true").unwrap(), Value::Bool(true));
        assert_eq!(parse("-3.5e2").unwrap(), Value::Num(-350.0));
        assert_eq!(parse("\"hi\"").unwrap(), Value::Str("hi".into()));
    }

    #[test]
    fn parses_nested() {
        let v = parse(r#"{"a": [1, 2, {"b": "c"}], "d": null}"#).unwrap();
        assert_eq!(v.get("a").unwrap().idx(1).unwrap().as_f64(), Some(2.0));
        assert_eq!(
            v.get("a").unwrap().idx(2).unwrap().get("b").unwrap().as_str(),
            Some("c")
        );
        assert_eq!(v.get("d"), Some(&Value::Null));
    }

    #[test]
    fn parses_escapes() {
        let v = parse(r#""a\n\t\"A\\""#).unwrap();
        assert_eq!(v.as_str(), Some("a\n\t\"A\\"));
    }

    #[test]
    fn parses_unicode_passthrough() {
        let v = parse("\"héllo ∞\"").unwrap();
        assert_eq!(v.as_str(), Some("héllo ∞"));
    }

    #[test]
    fn rejects_garbage() {
        assert!(parse("{").is_err());
        assert!(parse("[1,]").is_err());
        assert!(parse("12 34").is_err());
        assert!(parse("\"unterminated").is_err());
        assert!(parse("{\"a\" 1}").is_err());
    }

    #[test]
    fn roundtrip() {
        let src = r#"{"arr":[1,2.5,"x"],"nested":{"t":true},"z":null}"#;
        let v = parse(src).unwrap();
        let s = to_string(&v);
        assert_eq!(parse(&s).unwrap(), v);
    }

    #[test]
    fn parses_real_manifest() {
        // shape of the actual manifest.json
        let src = r#"{"format":"hlo-text","constants":{"dim":27},"artifacts":{"kl":{"file":"kl.hlo.txt","inputs":[{"name":"theta","shape":[27],"dtype":"f32"}]}}}"#;
        let v = parse(src).unwrap();
        assert_eq!(v.get("constants").unwrap().get("dim").unwrap().as_usize(), Some(27));
        let art = v.get("artifacts").unwrap().get("kl").unwrap();
        assert_eq!(
            art.get("inputs").unwrap().idx(0).unwrap().get("shape").unwrap().idx(0).unwrap().as_usize(),
            Some(27)
        );
    }
}
