//! Dtree: distributed dynamic scheduling (Pamnany et al. [12], §III-G).
//!
//! "Dtree organizes processes into a short tree for task distribution;
//! the tree fan-out is configurable ... parents in the tree distribute
//! batches of number ranges f–l ... in response to requests from child
//! processes. The size of each batch reduces as T is approached; this
//! balances load."
//!
//! Tasks are indices into the spatially-ordered catalog global array, so
//! contiguous batches are spatially compact (paper §III-D).

/// Half-open task range [first, last).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Range {
    pub first: usize,
    pub last: usize,
}

impl Range {
    pub fn len(&self) -> usize {
        self.last - self.first
    }

    pub fn is_empty(&self) -> bool {
        self.first >= self.last
    }
}

#[derive(Clone, Debug)]
pub struct DtreeConfig {
    /// children per tree node
    pub fanout: usize,
    /// smallest batch a parent hands out
    pub min_batch: usize,
    /// fraction of a node's remaining range handed to a requesting child
    pub child_frac: f64,
    /// fraction of the local range a worker claims per request
    pub work_frac: f64,
}

impl Default for DtreeConfig {
    fn default() -> Self {
        DtreeConfig { fanout: 8, min_batch: 1, child_frac: 0.5, work_frac: 0.25 }
    }
}

/// One per-process node of the tree. The whole tree lives in one address
/// space here (the simulator plays all ranks), but the protocol — who asks
/// whom, and how many hops a request takes — matches the distributed
/// original, and `hops` lets the cluster model charge network latency.
///
/// Ranges are delivered directly from the root pool to the requesting
/// leaf (guided self-scheduling: batch ∝ remaining / nprocs, shrinking
/// as T is approached). Intermediate tree nodes exist for *routing* —
/// requests climb parent links, which is what the hop-latency model
/// charges — but do not stash ranges: stashed ranges would strand work
/// inside one subtree, which the real Dtree avoids by forwarding.
#[derive(Clone, Debug)]
struct Node {
    local: Range,
}

/// The scheduler state over `nprocs` processes.
#[derive(Clone, Debug)]
pub struct Dtree {
    cfg: DtreeConfig,
    nodes: Vec<Node>,
    /// tasks not yet assigned to any node (owned by the root)
    root_remaining: Range,
    total: usize,
    issued: usize,
}

/// Result of a work request.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Grant {
    pub range: Range,
    /// tree levels traversed to satisfy the request (0 = local hit)
    pub hops: usize,
}

impl Dtree {
    pub fn new(cfg: DtreeConfig, nprocs: usize, total_tasks: usize) -> Dtree {
        assert!(nprocs > 0);
        Dtree {
            cfg,
            nodes: vec![Node { local: Range { first: 0, last: 0 } }; nprocs],
            root_remaining: Range { first: 0, last: total_tasks },
            total: total_tasks,
            issued: 0,
        }
    }

    fn parent(&self, p: usize) -> Option<usize> {
        if p == 0 {
            None
        } else {
            Some((p - 1) / self.cfg.fanout)
        }
    }

    /// Tree depth of process p (root = 0).
    pub fn depth(&self, p: usize) -> usize {
        let mut d = 0;
        let mut cur = p;
        while let Some(q) = self.parent(cur) {
            cur = q;
            d += 1;
        }
        d
    }

    pub fn total(&self) -> usize {
        self.total
    }

    pub fn remaining(&self) -> usize {
        self.total - self.issued
    }

    /// Take a batch from a node's local range with the shrinking policy.
    fn take_local(&mut self, p: usize) -> Option<Range> {
        let local = &mut self.nodes[p].local;
        if local.is_empty() {
            return None;
        }
        let want = ((local.len() as f64 * self.cfg.work_frac).ceil() as usize)
            .max(self.cfg.min_batch)
            .min(local.len());
        let r = Range { first: local.first, last: local.first + want };
        local.first += want;
        Some(r)
    }

    /// Refill node p's local range from the root pool (request routed up
    /// the tree; the batch is delivered directly). Returns hops used.
    fn refill(&mut self, p: usize) -> usize {
        if self.root_remaining.is_empty() {
            return self.depth(p);
        }
        let nprocs = self.nodes.len();
        // guided self-scheduling with the Dtree shrink: batch ∝ remaining
        let want = ((self.root_remaining.len() as f64 * self.cfg.child_frac
            / nprocs as f64)
            .ceil() as usize)
            .max(self.cfg.min_batch)
            .min(self.root_remaining.len());
        self.nodes[p].local = Range {
            first: self.root_remaining.first,
            last: self.root_remaining.first + want,
        };
        self.root_remaining.first += want;
        self.depth(p).max(1)
    }

    /// Request the next batch for process p. `None` = globally done.
    pub fn request(&mut self, p: usize) -> Option<Grant> {
        if let Some(range) = self.take_local(p) {
            self.issued += range.len();
            return Some(Grant { range, hops: 0 });
        }
        let hops = self.refill(p);
        if let Some(range) = self.take_local(p) {
            self.issued += range.len();
            return Some(Grant { range, hops });
        }
        None
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn drain(cfg: DtreeConfig, nprocs: usize, total: usize) -> Vec<Vec<Range>> {
        let mut dt = Dtree::new(cfg, nprocs, total);
        let mut got = vec![Vec::new(); nprocs];
        // round-robin requests until exhausted
        let mut active = true;
        while active {
            active = false;
            for p in 0..nprocs {
                if let Some(g) = dt.request(p) {
                    got[p].push(g.range);
                    active = true;
                }
            }
        }
        got
    }

    #[test]
    fn distributes_every_task_exactly_once() {
        for (nprocs, total) in [(1, 100), (8, 1000), (64, 3333), (256, 10_000)] {
            let got = drain(DtreeConfig::default(), nprocs, total);
            let mut seen = vec![false; total];
            for ranges in &got {
                for r in ranges {
                    for i in r.first..r.last {
                        assert!(!seen[i], "task {i} issued twice");
                        seen[i] = true;
                    }
                }
            }
            assert!(seen.iter().all(|&s| s), "nprocs={nprocs} total={total}");
        }
    }

    #[test]
    fn batches_shrink_toward_the_end() {
        let mut dt = Dtree::new(DtreeConfig::default(), 4, 10_000);
        let mut sizes = Vec::new();
        while let Some(g) = dt.request(0) {
            sizes.push(g.range.len());
            // other procs also draining
            for p in 1..4 {
                let _ = dt.request(p);
            }
        }
        assert!(sizes.len() > 4);
        let early: f64 =
            sizes[..3].iter().sum::<usize>() as f64 / 3.0;
        let late: f64 =
            sizes[sizes.len() - 3..].iter().sum::<usize>() as f64 / 3.0;
        assert!(late < early, "batches must shrink: early {early} late {late}");
        assert!(*sizes.last().unwrap() <= DtreeConfig::default().min_batch.max(4));
    }

    #[test]
    fn hops_bounded_by_tree_depth() {
        let cfg = DtreeConfig { fanout: 4, ..Default::default() };
        let mut dt = Dtree::new(cfg.clone(), 64, 5000);
        let max_depth = (0..64).map(|p| dt.depth(p)).max().unwrap();
        assert!(max_depth >= 2); // 64 procs at fanout 4 -> depth 3
        for p in 0..64 {
            if let Some(g) = dt.request(p) {
                assert!(g.hops <= max_depth, "hops {} depth {max_depth}", g.hops);
            }
        }
    }

    #[test]
    fn termination_returns_none_forever() {
        let mut dt = Dtree::new(DtreeConfig::default(), 2, 10);
        while dt.request(0).is_some() || dt.request(1).is_some() {}
        for _ in 0..5 {
            assert!(dt.request(0).is_none());
            assert!(dt.request(1).is_none());
        }
        assert_eq!(dt.remaining(), 0);
    }

    #[test]
    fn single_proc_gets_everything() {
        let got = drain(DtreeConfig::default(), 1, 57);
        assert_eq!(got[0].iter().map(Range::len).sum::<usize>(), 57);
    }

    #[test]
    fn ranges_are_contiguous_batches() {
        // spatial locality: each grant is one contiguous index range
        let got = drain(DtreeConfig::default(), 16, 2000);
        for ranges in &got {
            for r in ranges {
                assert!(r.last > r.first);
            }
        }
    }
}
