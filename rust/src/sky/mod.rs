//! Synthetic-universe generation.
//!
//! The paper's data substrate is SDSS DR12; we substitute skies drawn from
//! the Celeste generative model itself (DESIGN.md §4.1). Source positions
//! mix a uniform field with clusters, reproducing the spatial
//! non-uniformity the paper §III-C observes ("some regions of the sky have
//! many sources while other regions have few to none") — the origin of the
//! load imbalance its scheduler exists to fix.

use crate::model::{GalaxyShape, SourceParams};
use crate::prng::Rng;

/// Configuration for a synthetic sky.
#[derive(Clone, Debug)]
pub struct SkyConfig {
    /// global extent, pixels
    pub width: f64,
    pub height: f64,
    pub n_sources: usize,
    /// fraction of sources that are galaxies
    pub frac_galaxy: f64,
    /// fraction of sources placed in clusters (vs uniform)
    pub frac_clustered: f64,
    /// number of clusters
    pub n_clusters: usize,
    /// cluster standard deviation, pixels
    pub cluster_sd: f64,
    /// lognormal flux prior: (mu, sigma) of log flux — stars
    pub flux_star: (f64, f64),
    /// lognormal flux prior — galaxies
    pub flux_gal: (f64, f64),
    /// color means/SDs per population
    pub color_mean_star: [f64; 4],
    pub color_mean_gal: [f64; 4],
    pub color_sd: f64,
    /// galaxy scale lognormal: (mu of log scale, sigma)
    pub scale_lognorm: (f64, f64),
    pub seed: u64,
}

impl Default for SkyConfig {
    fn default() -> Self {
        SkyConfig {
            width: 2048.0,
            height: 1361.0,
            n_sources: 500,
            frac_galaxy: 0.35,
            frac_clustered: 0.4,
            n_clusters: 6,
            cluster_sd: 60.0,
            flux_star: (4.0, 1.2),
            flux_gal: (4.5, 1.2),
            color_mean_star: [0.5, 0.4, 0.2, 0.1],
            color_mean_gal: [0.8, 0.5, 0.3, 0.2],
            color_sd: 0.2,
            scale_lognorm: (0.5, 0.4),
            seed: 42,
        }
    }
}

/// A synthetic universe: ground-truth sources plus extent.
#[derive(Clone, Debug)]
pub struct Universe {
    pub width: f64,
    pub height: f64,
    pub sources: Vec<SourceParams>,
}

/// Draw a universe from the generative prior.
pub fn generate(cfg: &SkyConfig) -> Universe {
    let mut rng = Rng::new(cfg.seed);
    // cluster centers
    let centers: Vec<(f64, f64)> = (0..cfg.n_clusters)
        .map(|_| {
            (
                rng.uniform_in(0.1 * cfg.width, 0.9 * cfg.width),
                rng.uniform_in(0.1 * cfg.height, 0.9 * cfg.height),
            )
        })
        .collect();

    let margin = 4.0; // keep centers inside the sky
    let mut sources = Vec::with_capacity(cfg.n_sources);
    for _ in 0..cfg.n_sources {
        let pos = if !centers.is_empty() && rng.uniform() < cfg.frac_clustered {
            let c = centers[rng.below(centers.len() as u64) as usize];
            (
                (c.0 + rng.normal() * cfg.cluster_sd).clamp(margin, cfg.width - margin),
                (c.1 + rng.normal() * cfg.cluster_sd).clamp(margin, cfg.height - margin),
            )
        } else {
            (
                rng.uniform_in(margin, cfg.width - margin),
                rng.uniform_in(margin, cfg.height - margin),
            )
        };
        let is_galaxy = rng.uniform() < cfg.frac_galaxy;
        let (fmu, fsd) = if is_galaxy { cfg.flux_gal } else { cfg.flux_star };
        let flux_r = rng.lognormal(fmu, fsd);
        let cmean = if is_galaxy { cfg.color_mean_gal } else { cfg.color_mean_star };
        let mut colors = [0.0; 4];
        for (c, m) in colors.iter_mut().zip(cmean) {
            *c = rng.normal_ms(m, cfg.color_sd);
        }
        let shape = if is_galaxy {
            GalaxyShape {
                p_dev: rng.uniform_in(0.05, 0.95),
                axis_ratio: rng.uniform_in(0.15, 0.95),
                angle: rng.uniform_in(0.0, std::f64::consts::PI),
                scale: rng.lognormal(cfg.scale_lognorm.0, cfg.scale_lognorm.1).clamp(0.3, 8.0),
            }
        } else {
            GalaxyShape::point_like()
        };
        sources.push(SourceParams { pos, is_galaxy, flux_r, colors, shape });
    }
    Universe { width: cfg.width, height: cfg.height, sources }
}

/// Per-cell source counts on a grid — quantifies spatial non-uniformity
/// (used by the fig1/fig4 harnesses and by tests).
pub fn density_grid(u: &Universe, cells_x: usize, cells_y: usize) -> Vec<usize> {
    let mut grid = vec![0usize; cells_x * cells_y];
    for s in &u.sources {
        let cx = ((s.pos.0 / u.width) * cells_x as f64).min(cells_x as f64 - 1.0) as usize;
        let cy = ((s.pos.1 / u.height) * cells_y as f64).min(cells_y as f64 - 1.0) as usize;
        grid[cy * cells_x + cx] += 1;
    }
    grid
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn generates_requested_count() {
        let u = generate(&SkyConfig { n_sources: 321, ..Default::default() });
        assert_eq!(u.sources.len(), 321);
    }

    #[test]
    fn positions_in_bounds() {
        let u = generate(&SkyConfig::default());
        for s in &u.sources {
            assert!(s.pos.0 >= 0.0 && s.pos.0 <= u.width);
            assert!(s.pos.1 >= 0.0 && s.pos.1 <= u.height);
        }
    }

    #[test]
    fn galaxy_fraction_approx() {
        let cfg = SkyConfig { n_sources: 5000, frac_galaxy: 0.35, ..Default::default() };
        let u = generate(&cfg);
        let ng = u.sources.iter().filter(|s| s.is_galaxy).count();
        let frac = ng as f64 / 5000.0;
        assert!((frac - 0.35).abs() < 0.03, "frac {frac}");
    }

    #[test]
    fn deterministic_given_seed() {
        let a = generate(&SkyConfig::default());
        let b = generate(&SkyConfig::default());
        assert_eq!(a.sources.len(), b.sources.len());
        for (x, y) in a.sources.iter().zip(&b.sources) {
            assert_eq!(x.pos, y.pos);
            assert_eq!(x.flux_r, y.flux_r);
        }
    }

    #[test]
    fn clustering_creates_imbalance() {
        // clustered skies must have a markedly higher max/mean cell count
        let flat = generate(&SkyConfig {
            n_sources: 4000,
            frac_clustered: 0.0,
            seed: 1,
            ..Default::default()
        });
        let lumpy = generate(&SkyConfig {
            n_sources: 4000,
            frac_clustered: 0.7,
            n_clusters: 4,
            cluster_sd: 40.0,
            seed: 1,
            ..Default::default()
        });
        let peak = |u: &Universe| {
            let g = density_grid(u, 16, 16);
            let mean = g.iter().sum::<usize>() as f64 / g.len() as f64;
            g.iter().copied().max().unwrap() as f64 / mean
        };
        assert!(peak(&lumpy) > 2.0 * peak(&flat), "lumpy {} flat {}", peak(&lumpy), peak(&flat));
    }

    #[test]
    fn galaxies_have_varied_shapes() {
        let u = generate(&SkyConfig { n_sources: 2000, ..Default::default() });
        let scales: Vec<f64> = u
            .sources
            .iter()
            .filter(|s| s.is_galaxy)
            .map(|s| s.shape.scale)
            .collect();
        let mean = scales.iter().sum::<f64>() / scales.len() as f64;
        let var = scales.iter().map(|s| (s - mean).powi(2)).sum::<f64>() / scales.len() as f64;
        assert!(var > 0.01);
    }
}
