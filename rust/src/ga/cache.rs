//! Byte-capacity LRU cache — the paper's "process-level cache of images
//! and catalog entries" (§III-D).

use std::collections::HashMap;

/// LRU over u64 keys with a byte-capacity bound.
#[derive(Clone, Debug)]
pub struct LruCache {
    capacity_bytes: f64,
    used_bytes: f64,
    /// key -> (bytes, last-use tick)
    map: HashMap<u64, (f64, u64)>,
    tick: u64,
    pub hits: u64,
    pub misses: u64,
}

impl LruCache {
    pub fn new(capacity_bytes: f64) -> LruCache {
        LruCache {
            capacity_bytes,
            used_bytes: 0.0,
            map: HashMap::new(),
            tick: 0,
            hits: 0,
            misses: 0,
        }
    }

    pub fn len(&self) -> usize {
        self.map.len()
    }

    pub fn is_empty(&self) -> bool {
        self.map.is_empty()
    }

    pub fn used_bytes(&self) -> f64 {
        self.used_bytes
    }

    /// Probe the cache; refreshes recency on hit.
    pub fn contains(&mut self, key: u64) -> bool {
        self.tick += 1;
        if let Some(e) = self.map.get_mut(&key) {
            e.1 = self.tick;
            self.hits += 1;
            true
        } else {
            self.misses += 1;
            false
        }
    }

    /// Insert a key, evicting least-recently-used entries as needed.
    /// Objects larger than the whole capacity are admitted alone.
    pub fn insert(&mut self, key: u64, bytes: f64) {
        self.tick += 1;
        if let Some(e) = self.map.get_mut(&key) {
            e.1 = self.tick;
            return;
        }
        while !self.map.is_empty() && self.used_bytes + bytes > self.capacity_bytes {
            // evict LRU
            let (&victim, _) = self
                .map
                .iter()
                .min_by(|a, b| a.1 .1.cmp(&b.1 .1))
                .unwrap();
            let (vb, _) = self.map.remove(&victim).unwrap();
            self.used_bytes -= vb;
        }
        self.map.insert(key, (bytes, self.tick));
        self.used_bytes += bytes;
    }

    pub fn hit_rate(&self) -> f64 {
        let total = self.hits + self.misses;
        if total == 0 {
            0.0
        } else {
            self.hits as f64 / total as f64
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn hit_and_miss_accounting() {
        let mut c = LruCache::new(100.0);
        assert!(!c.contains(1));
        c.insert(1, 10.0);
        assert!(c.contains(1));
        assert_eq!(c.hits, 1);
        assert_eq!(c.misses, 1);
        assert!((c.hit_rate() - 0.5).abs() < 1e-12);
    }

    #[test]
    fn evicts_lru_on_capacity() {
        let mut c = LruCache::new(30.0);
        c.insert(1, 10.0);
        c.insert(2, 10.0);
        c.insert(3, 10.0);
        // touch 1 so 2 becomes LRU
        assert!(c.contains(1));
        c.insert(4, 10.0);
        assert!(!c.contains(2), "2 should be evicted");
        assert!(c.contains(1));
        assert!(c.contains(3));
        assert!(c.contains(4));
        assert!(c.used_bytes() <= 30.0);
    }

    #[test]
    fn oversized_object_admitted_alone() {
        let mut c = LruCache::new(10.0);
        c.insert(1, 5.0);
        c.insert(2, 100.0);
        assert!(c.contains(2));
        assert!(!c.contains(1));
    }

    #[test]
    fn reinsert_refreshes_not_duplicates() {
        let mut c = LruCache::new(20.0);
        c.insert(1, 10.0);
        c.insert(1, 10.0);
        assert_eq!(c.len(), 1);
        assert_eq!(c.used_bytes(), 10.0);
    }
}
