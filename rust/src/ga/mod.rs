//! Global arrays (PGAS) over a modeled interconnect — the stand-in for
//! the paper's Garbo library (§III-F): "we load all images from disk into
//! the memory of all the participating processes, using a global array
//! implementation, thus converting a slow, disk-bound operation into a
//! much faster one-sided RMA operation on a high-performance interconnect
//! fabric."
//!
//! Real MPI-3 RMA on Cray Aries is substituted by an explicit fabric
//! model (DESIGN.md §4.5): per-node NIC bandwidth plus a shared bisection
//! resource, both advancing *simulated* time, so a 256-node run executes
//! on one host while reproducing the saturation behaviour of Figs 4–6.

pub mod cache;

pub use cache::LruCache;

/// Fabric parameters (defaults approximate a Cray Aries dragonfly scaled
/// to the simulation's synthetic image sizes).
#[derive(Clone, Debug)]
pub struct FabricConfig {
    /// one-sided get latency, seconds
    pub latency: f64,
    /// per-node NIC (injection) bandwidth, bytes/second
    pub nic_bw: f64,
    /// total bisection bandwidth shared by all remote transfers, B/s
    pub bisection_bw: f64,
    /// local (same-process) copy bandwidth, B/s
    pub local_bw: f64,
}

impl Default for FabricConfig {
    fn default() -> Self {
        FabricConfig {
            latency: 5e-6,
            nic_bw: 8e9,
            bisection_bw: 350e9,
            local_bw: 50e9,
        }
    }
}

/// Simulated-time fabric: tracks per-node NIC availability and the shared
/// bisection pipe.
#[derive(Clone, Debug)]
pub struct Fabric {
    pub cfg: FabricConfig,
    nic_free: Vec<f64>,
    bis_free: f64,
    /// total bytes moved (metrics)
    pub bytes_moved: f64,
    /// total transfer count (metrics)
    pub transfers: u64,
    /// bytes through each node's NIC, both directions (per-node
    /// breakdowns; intra-node copies never touch a NIC and are excluded)
    pub node_bytes: Vec<f64>,
}

impl Fabric {
    pub fn new(cfg: FabricConfig, nodes: usize) -> Fabric {
        Fabric {
            cfg,
            nic_free: vec![0.0; nodes],
            bis_free: 0.0,
            bytes_moved: 0.0,
            transfers: 0,
            node_bytes: vec![0.0; nodes],
        }
    }

    /// Schedule a one-sided get of `bytes` from `src_node` to `dst_node`
    /// starting at `now`; returns the completion time.
    pub fn get(&mut self, now: f64, bytes: f64, src_node: usize, dst_node: usize) -> f64 {
        self.bytes_moved += bytes;
        self.transfers += 1;
        if src_node == dst_node {
            // intra-node: memory copy only
            return now + self.cfg.latency + bytes / self.cfg.local_bw;
        }
        self.node_bytes[src_node] += bytes;
        self.node_bytes[dst_node] += bytes;
        // serialize on both NICs
        let nic_start = now.max(self.nic_free[src_node]).max(self.nic_free[dst_node]);
        let nic_time = bytes / self.cfg.nic_bw;
        // and on the shared bisection pipe
        let bis_start = now.max(self.bis_free);
        let bis_time = bytes / self.cfg.bisection_bw;
        let done = (nic_start + nic_time).max(bis_start + bis_time) + self.cfg.latency;
        self.nic_free[src_node] = nic_start + nic_time;
        self.nic_free[dst_node] = nic_start + nic_time;
        self.bis_free = bis_start + bis_time;
        done
    }
}

/// Placement of a distributed array's chunks across processes.
#[derive(Clone, Debug)]
pub struct GlobalArray {
    /// bytes per chunk (chunk i = element i, e.g. one field's 5 bands)
    pub chunk_bytes: Vec<f64>,
    /// owning process of each chunk
    pub owner: Vec<usize>,
    pub nprocs: usize,
}

impl GlobalArray {
    /// Block-cyclic placement of `chunks` across `nprocs` processes.
    pub fn round_robin(chunk_bytes: Vec<f64>, nprocs: usize) -> GlobalArray {
        let owner = (0..chunk_bytes.len()).map(|i| i % nprocs).collect();
        GlobalArray { chunk_bytes, owner, nprocs }
    }

    pub fn owner_of(&self, chunk: usize) -> usize {
        self.owner[chunk]
    }

    pub fn bytes_of(&self, chunk: usize) -> f64 {
        self.chunk_bytes[chunk]
    }

    pub fn total_bytes(&self) -> f64 {
        self.chunk_bytes.iter().sum()
    }

    /// Bytes stored by each process (for phase-1 load accounting).
    pub fn bytes_per_proc(&self) -> Vec<f64> {
        let mut v = vec![0.0; self.nprocs];
        for (i, &b) in self.chunk_bytes.iter().enumerate() {
            v[self.owner[i]] += b;
        }
        v
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn local_get_is_fast() {
        let mut f = Fabric::new(FabricConfig::default(), 4);
        let done = f.get(0.0, 120e6, 1, 1);
        // 120 MB local at 50 GB/s = 2.4 ms
        assert!(done < 0.01, "{done}");
    }

    #[test]
    fn remote_get_costs_nic_time() {
        let mut f = Fabric::new(FabricConfig::default(), 4);
        let done = f.get(0.0, 120e6, 0, 1);
        // 120 MB at 8 GB/s = 15 ms
        assert!((done - 0.015).abs() < 0.005, "{done}");
    }

    #[test]
    fn nic_serializes_transfers_to_same_node() {
        let mut f = Fabric::new(FabricConfig::default(), 4);
        let d1 = f.get(0.0, 80e6, 0, 1);
        let d2 = f.get(0.0, 80e6, 2, 1); // same destination NIC
        assert!(d2 > d1, "second transfer must queue: {d1} {d2}");
    }

    #[test]
    fn bisection_saturates_under_aggregate_load() {
        // many simultaneous node-pairs: each pair's NICs are free, but the
        // shared bisection pipe must back up.
        let cfg = FabricConfig::default();
        let nodes = 512;
        let mut f = Fabric::new(cfg.clone(), nodes);
        let bytes = 120e6;
        let mut last = 0.0f64;
        for p in 0..(nodes / 2) {
            last = last.max(f.get(0.0, bytes, 2 * p, 2 * p + 1));
        }
        let nic_only = cfg.latency + bytes / cfg.nic_bw;
        assert!(
            last > 5.0 * nic_only,
            "bisection must dominate at scale: {last} vs {nic_only}"
        );
    }

    #[test]
    fn fabric_accounts_bytes() {
        let mut f = Fabric::new(FabricConfig::default(), 2);
        f.get(0.0, 10.0, 0, 1);
        f.get(0.0, 20.0, 0, 0);
        assert_eq!(f.bytes_moved, 30.0);
        assert_eq!(f.transfers, 2);
        // per-NIC accounting: the remote transfer crosses both NICs, the
        // local copy crosses neither
        assert_eq!(f.node_bytes, vec![10.0, 10.0]);
    }

    #[test]
    fn round_robin_placement_balanced() {
        let ga = GlobalArray::round_robin(vec![100.0; 64], 8);
        let per = ga.bytes_per_proc();
        for p in per {
            assert_eq!(p, 800.0);
        }
        assert_eq!(ga.owner_of(9), 1);
        assert_eq!(ga.total_bytes(), 6400.0);
    }
}
