//! Adapter: the compiled negated-ELBO as an `optim` objective.

use crate::imaging::Patch;
use crate::linalg::Mat;
use crate::model::layout as L;
use crate::optim::{GradObjective, NewtonObjective};

use super::elbo::{ElboEngine, LikeEngine};

/// The per-source optimization problem: minimize KL − Σ like over θ.
pub struct SourceObjective<'a> {
    pub engine: &'a ElboEngine<'a>,
    pub patches: &'a [Patch],
    /// which likelihood artifact backs value_grad (Newton always uses
    /// the autodiff artifact for its Hessian)
    pub like: LikeEngine,
    /// count of failed artifact executions (observability)
    pub errors: usize,
}

impl<'a> SourceObjective<'a> {
    pub fn new(engine: &'a ElboEngine<'a>, patches: &'a [Patch]) -> Self {
        SourceObjective { engine, patches, like: LikeEngine::AutoDiff, errors: 0 }
    }

    pub fn with_engine(mut self, like: LikeEngine) -> Self {
        self.like = like;
        self
    }
}

impl GradObjective for SourceObjective<'_> {
    fn dim(&self) -> usize {
        L::DIM
    }

    fn value_grad(&mut self, x: &[f64]) -> Option<(f64, Vec<f64>)> {
        match self.engine.neg_elbo_vg(x, self.patches, self.like) {
            Ok(v) if v.0.is_finite() => Some(v),
            Ok(_) => None,
            Err(_) => {
                self.errors += 1;
                None
            }
        }
    }
}

impl NewtonObjective for SourceObjective<'_> {
    fn value_grad_hess(&mut self, x: &[f64]) -> Option<(f64, Vec<f64>, Mat)> {
        match self.engine.neg_elbo_vgh(x, self.patches) {
            Ok(v) if v.0.is_finite() => Some(v),
            Ok(_) => None,
            Err(_) => {
                self.errors += 1;
                None
            }
        }
    }
}
