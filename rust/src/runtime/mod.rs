//! PJRT runtime: load AOT-compiled HLO artifacts and execute them from the
//! inference path (Python never runs here).

pub mod elbo;
pub mod objective;
pub mod optimize;
pub mod executor;
pub mod manifest;

pub use elbo::{ElboEngine, LikeEngine};
pub use executor::{load_default, pjrt_smoke, Runtime};
pub use objective::SourceObjective;
pub use optimize::{optimize_source, SourceFit};
pub use manifest::{default_artifact_dir, ArtifactSig, Manifest, TensorSig};
