//! PJRT executor: compile HLO-text artifacts once, execute many times.
//!
//! Adapted from /opt/xla-example/load_hlo: `HloModuleProto::from_text_file`
//! → `XlaComputation::from_proto` → `client.compile` → `execute`.
//!
//! `PjRtClient` is `Rc`-based (not `Send`), so a `Runtime` is bound to one
//! OS thread; the cluster layer creates one per worker thread via
//! `thread_local!` (see `coordinator::worker`).

use std::cell::Cell;
use std::collections::BTreeMap;
use std::path::Path;

use anyhow::{anyhow, bail, Context, Result};

use super::manifest::{ArtifactSig, Manifest};

/// A compiled artifact plus its signature.
pub struct Loaded {
    pub sig: ArtifactSig,
    exe: xla::PjRtLoadedExecutable,
}

/// One thread's PJRT runtime.
pub struct Runtime {
    pub manifest: Manifest,
    #[allow(dead_code)]
    client: xla::PjRtClient,
    exes: BTreeMap<String, Loaded>,
    /// number of artifact executions (metrics)
    pub exec_count: Cell<u64>,
    /// accumulated execution wall time, ns (metrics)
    pub exec_ns: Cell<u64>,
}

impl Runtime {
    /// Compile every artifact in the manifest.
    pub fn load(dir: &Path) -> Result<Runtime> {
        Self::load_subset(dir, &[])
    }

    /// Compile a subset of artifacts (empty = all). Compiling `like_ad`
    /// dominates startup, so harnesses that only need the renderer can
    /// skip it.
    pub fn load_subset(dir: &Path, names: &[&str]) -> Result<Runtime> {
        let manifest = Manifest::load(dir)?;
        let client = xla::PjRtClient::cpu()
            .map_err(|e| anyhow!("PjRtClient::cpu: {e:?}"))?;
        let mut exes = BTreeMap::new();
        for (name, sig) in &manifest.artifacts {
            if !names.is_empty() && !names.contains(&name.as_str()) {
                continue;
            }
            let proto = xla::HloModuleProto::from_text_file(&sig.path)
                .map_err(|e| anyhow!("parsing {:?}: {e:?}", sig.path))?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = client
                .compile(&comp)
                .map_err(|e| anyhow!("compiling {name}: {e:?}"))?;
            exes.insert(name.clone(), Loaded { sig: sig.clone(), exe });
        }
        Ok(Runtime { manifest, client, exes, exec_count: Cell::new(0), exec_ns: Cell::new(0) })
    }

    pub fn has(&self, name: &str) -> bool {
        self.exes.contains_key(name)
    }

    /// Execute an artifact. `inputs` must match the manifest signature
    /// (flattened row-major f64); returns one flattened vec per output.
    pub fn execute(&self, name: &str, inputs: &[&[f64]]) -> Result<Vec<Vec<f64>>> {
        let loaded = self
            .exes
            .get(name)
            .ok_or_else(|| anyhow!("artifact {name} not loaded"))?;
        let sig = &loaded.sig;
        if inputs.len() != sig.inputs.len() {
            bail!(
                "{name}: expected {} inputs, got {}",
                sig.inputs.len(),
                inputs.len()
            );
        }
        let mut literals = Vec::with_capacity(inputs.len());
        for (data, tsig) in inputs.iter().zip(&sig.inputs) {
            if data.len() != tsig.numel() {
                bail!(
                    "{name}.{}: expected {} elements ({:?}), got {}",
                    tsig.name,
                    tsig.numel(),
                    tsig.shape,
                    data.len()
                );
            }
            let lit = xla::Literal::vec1(data);
            let lit = if tsig.shape.len() == 1 {
                lit
            } else {
                let dims: Vec<i64> = tsig.shape.iter().map(|&d| d as i64).collect();
                lit.reshape(&dims)
                    .map_err(|e| anyhow!("{name}.{}: reshape: {e:?}", tsig.name))?
            };
            literals.push(lit);
        }

        let t0 = std::time::Instant::now();
        let result = loaded
            .exe
            .execute::<xla::Literal>(&literals)
            .map_err(|e| anyhow!("executing {name}: {e:?}"))?;
        self.exec_count.set(self.exec_count.get() + 1);
        self.exec_ns
            .set(self.exec_ns.get() + t0.elapsed().as_nanos() as u64);

        let lit = result[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow!("{name}: to_literal: {e:?}"))?;
        // aot.py lowers with return_tuple=True: output is always a tuple
        let parts = lit
            .to_tuple()
            .map_err(|e| anyhow!("{name}: to_tuple: {e:?}"))?;
        if parts.len() != sig.outputs.len() {
            bail!(
                "{name}: expected {} outputs, got {}",
                sig.outputs.len(),
                parts.len()
            );
        }
        let mut out = Vec::with_capacity(parts.len());
        for (part, tsig) in parts.into_iter().zip(&sig.outputs) {
            let v = part
                .to_vec::<f64>()
                .map_err(|e| anyhow!("{name}.{}: to_vec: {e:?}", tsig.name))?;
            if v.len() != tsig.numel() {
                bail!(
                    "{name}.{}: output has {} elements, signature says {}",
                    tsig.name,
                    v.len(),
                    tsig.numel()
                );
            }
            out.push(v);
        }
        Ok(out)
    }

    /// Mean execution latency in microseconds (metrics).
    pub fn mean_exec_us(&self) -> f64 {
        let n = self.exec_count.get();
        if n == 0 {
            0.0
        } else {
            self.exec_ns.get() as f64 / n as f64 / 1000.0
        }
    }
}

/// Smoke check that the PJRT CPU client initializes.
pub fn pjrt_smoke() -> Result<String> {
    let client = xla::PjRtClient::cpu().map_err(|e| anyhow!("{e:?}"))?;
    Ok(format!(
        "platform={} devices={}",
        client.platform_name(),
        client.device_count()
    ))
}

/// Load the runtime from the default artifact dir with a helpful error.
pub fn load_default() -> Result<Runtime> {
    let dir = super::manifest::default_artifact_dir();
    Runtime::load(&dir).with_context(|| {
        format!("loading artifacts from {dir:?} (set CELESTE_ARTIFACTS or run `make artifacts`)")
    })
}
