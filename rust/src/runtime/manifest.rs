//! Artifact manifest: the contract between `python/compile/aot.py` and the
//! Rust runtime. Parsed from `artifacts/manifest.json` and validated
//! against the compiled-in layout so the two sides cannot drift.

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use anyhow::{anyhow, bail, Context, Result};

use crate::jsonlite::{self, Value};
use crate::model::layout as L;

/// One tensor in an artifact signature.
#[derive(Clone, Debug, PartialEq)]
pub struct TensorSig {
    pub name: String,
    pub shape: Vec<usize>,
}

impl TensorSig {
    pub fn numel(&self) -> usize {
        self.shape.iter().product::<usize>().max(1)
    }
}

/// One artifact: HLO file + typed signature.
#[derive(Clone, Debug)]
pub struct ArtifactSig {
    pub name: String,
    pub path: PathBuf,
    pub inputs: Vec<TensorSig>,
    pub outputs: Vec<TensorSig>,
}

/// The parsed, validated manifest.
#[derive(Clone, Debug)]
pub struct Manifest {
    pub dir: PathBuf,
    pub artifacts: BTreeMap<String, ArtifactSig>,
}

fn tensor_list(v: &Value, key: &str) -> Result<Vec<TensorSig>> {
    v.get(key)
        .and_then(Value::as_arr)
        .ok_or_else(|| anyhow!("missing {key}"))?
        .iter()
        .map(|t| {
            let name = t
                .get("name")
                .and_then(Value::as_str)
                .ok_or_else(|| anyhow!("tensor missing name"))?
                .to_string();
            let shape = t
                .get("shape")
                .and_then(Value::as_arr)
                .ok_or_else(|| anyhow!("tensor {name} missing shape"))?
                .iter()
                .map(|d| d.as_usize().ok_or_else(|| anyhow!("bad dim")))
                .collect::<Result<Vec<_>>>()?;
            let dtype = t.get("dtype").and_then(Value::as_str).unwrap_or("f64");
            if dtype != "f64" {
                bail!("tensor {name}: only f64 supported, got {dtype}");
            }
            Ok(TensorSig { name, shape })
        })
        .collect()
}

/// Constants that must agree between Python and Rust.
fn check_constants(c: &Value) -> Result<()> {
    let want: &[(&str, f64)] = &[
        ("dim", L::DIM as f64),
        ("prior_dim", L::PRIOR_DIM as f64),
        ("n_bands", L::N_BANDS as f64),
        ("ref_band", L::REF_BAND as f64),
        ("patch", L::PATCH as f64),
        ("k_psf", L::K_PSF as f64),
        ("psf_params", L::PSF_PARAMS as f64),
        ("k_star", L::K_STAR as f64),
        ("k_gal", L::K_GAL as f64),
        ("comp_params", L::COMP_PARAMS as f64),
        ("i_a", L::I_A as f64),
        ("i_loc", L::I_LOC as f64),
        ("i_flux_star", L::I_FLUX_STAR as f64),
        ("i_flux_gal", L::I_FLUX_GAL as f64),
        ("i_color_mean_star", L::I_COLOR_MEAN_STAR as f64),
        ("i_color_mean_gal", L::I_COLOR_MEAN_GAL as f64),
        ("i_color_var_star", L::I_COLOR_VAR_STAR as f64),
        ("i_color_var_gal", L::I_COLOR_VAR_GAL as f64),
        ("i_shape", L::I_SHAPE as f64),
        ("ridge", L::RIDGE),
    ];
    // shape priors (2-tuples)
    for (key, (m, v)) in [
        ("shape_prior_pdev", L::SHAPE_PRIOR_PDEV),
        ("shape_prior_axis", L::SHAPE_PRIOR_AXIS),
        ("shape_prior_scale", L::SHAPE_PRIOR_SCALE),
    ] {
        let arr = c
            .get(key)
            .and_then(Value::as_arr)
            .ok_or_else(|| anyhow!("manifest constants missing {key}"))?;
        let got_m = arr.first().and_then(Value::as_f64).unwrap_or(f64::NAN);
        let got_v = arr.get(1).and_then(Value::as_f64).unwrap_or(f64::NAN);
        if (got_m - m).abs() > 1e-12 || (got_v - v).abs() > 1e-12 {
            bail!("layout drift in {key}");
        }
    }
    for (key, expect) in want {
        let got = c
            .get(key)
            .and_then(Value::as_f64)
            .ok_or_else(|| anyhow!("manifest constants missing {key}"))?;
        if (got - expect).abs() > 1e-12 {
            bail!("layout drift: {key} = {got} in manifest, {expect} in rust");
        }
    }
    // profile tables
    for (key, table) in [
        ("profile_exp_amp", &L::PROFILE_EXP_AMP),
        ("profile_exp_var", &L::PROFILE_EXP_VAR),
        ("profile_dev_amp", &L::PROFILE_DEV_AMP),
        ("profile_dev_var", &L::PROFILE_DEV_VAR),
    ] {
        let arr = c
            .get(key)
            .and_then(Value::as_arr)
            .ok_or_else(|| anyhow!("manifest constants missing {key}"))?;
        if arr.len() != table.len() {
            bail!("layout drift: {key} length");
        }
        for (a, b) in arr.iter().zip(table.iter()) {
            if (a.as_f64().unwrap_or(f64::NAN) - b).abs() > 1e-12 {
                bail!("layout drift in {key}");
            }
        }
    }
    Ok(())
}

impl Manifest {
    /// Load and validate `<dir>/manifest.json`.
    pub fn load(dir: &Path) -> Result<Manifest> {
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {path:?} — run `make artifacts` first"))?;
        let v = jsonlite::parse(&text).map_err(|e| anyhow!("{path:?}: {e}"))?;
        if v.get("format").and_then(Value::as_str) != Some("hlo-text") {
            bail!("manifest format must be hlo-text");
        }
        check_constants(v.get("constants").ok_or_else(|| anyhow!("missing constants"))?)?;

        let mut artifacts = BTreeMap::new();
        for (name, art) in v
            .get("artifacts")
            .and_then(Value::as_obj)
            .ok_or_else(|| anyhow!("missing artifacts"))?
        {
            let file = art
                .get("file")
                .and_then(Value::as_str)
                .ok_or_else(|| anyhow!("artifact {name} missing file"))?;
            let path = dir.join(file);
            if !path.exists() {
                bail!("artifact file missing: {path:?}");
            }
            artifacts.insert(
                name.clone(),
                ArtifactSig {
                    name: name.clone(),
                    path,
                    inputs: tensor_list(art, "inputs")?,
                    outputs: tensor_list(art, "outputs")?,
                },
            );
        }
        for required in [L::ART_LIKE_AD, L::ART_LIKE_PALLAS, L::ART_KL, L::ART_RENDER] {
            if !artifacts.contains_key(required) {
                bail!("manifest missing required artifact {required}");
            }
        }
        Ok(Manifest { dir: dir.to_path_buf(), artifacts })
    }

    pub fn get(&self, name: &str) -> Result<&ArtifactSig> {
        self.artifacts
            .get(name)
            .ok_or_else(|| anyhow!("unknown artifact {name}"))
    }
}

/// Locate the artifacts directory: $CELESTE_ARTIFACTS or ./artifacts.
pub fn default_artifact_dir() -> PathBuf {
    std::env::var_os("CELESTE_ARTIFACTS")
        .map(PathBuf::from)
        .unwrap_or_else(|| PathBuf::from("artifacts"))
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Integration coverage for the real manifest lives in
    /// rust/tests/runtime_integration.rs (requires `make artifacts`).
    #[test]
    fn tensor_numel() {
        let t = TensorSig { name: "x".into(), shape: vec![5, 32, 32] };
        assert_eq!(t.numel(), 5 * 32 * 32);
        let s = TensorSig { name: "scalar".into(), shape: vec![] };
        assert_eq!(s.numel(), 1);
    }

    #[test]
    fn rejects_drifted_constants() {
        let json = r#"{"dim": 99}"#;
        let v = jsonlite::parse(json).unwrap();
        assert!(check_constants(&v).is_err());
    }
}
