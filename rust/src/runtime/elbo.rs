//! The ELBO engine: composes the compiled artifacts into the per-source
//! objective the optimizer minimizes.
//!
//! objective(θ) = KL(θ) − Σ_epochs like(θ, patch_e)      (negated ELBO)
//!
//! The likelihood is additive across epochs (independent Poisson
//! observations), so value/grad/Hessian all sum; the KL term appears once.

use anyhow::Result;

use crate::imaging::Patch;
use crate::linalg::Mat;
use crate::model::layout as L;
use crate::model::Prior;

use super::executor::Runtime;

/// Which compiled likelihood path to use.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum LikeEngine {
    /// pure-jnp autodiff artifact: value + grad + dense Hessian
    AutoDiff,
    /// Pallas manual-gradient artifact: value + grad (no Hessian)
    PallasManual,
}

/// Per-source objective evaluator backed by compiled artifacts.
pub struct ElboEngine<'rt> {
    pub rt: &'rt Runtime,
    prior_vec: Vec<f64>,
}

const D: usize = L::DIM;

impl<'rt> ElboEngine<'rt> {
    pub fn new(rt: &'rt Runtime, prior: &Prior) -> Self {
        ElboEngine { rt, prior_vec: prior.to_vec().to_vec() }
    }

    /// KL(q‖prior): (value, grad, hess).
    pub fn kl_vgh(&self, theta: &[f64]) -> Result<(f64, Vec<f64>, Mat)> {
        let out = self.rt.execute(L::ART_KL, &[theta, &self.prior_vec])?;
        Ok(unpack_vgh(&out))
    }

    /// One epoch's expected log-likelihood: (value, grad, hess), autodiff.
    pub fn like_vgh(&self, theta: &[f64], p: &Patch) -> Result<(f64, Vec<f64>, Mat)> {
        let out = self.rt.execute(
            L::ART_LIKE_AD,
            &[theta, &p.pixels, &p.bg, &p.mask, &p.psf, &p.gain],
        )?;
        Ok(unpack_vgh(&out))
    }

    /// One epoch's expected log-likelihood: (value, grad), Pallas manual.
    pub fn like_vg_pallas(&self, theta: &[f64], p: &Patch) -> Result<(f64, Vec<f64>)> {
        let out = self.rt.execute(
            L::ART_LIKE_PALLAS,
            &[theta, &p.pixels, &p.bg, &p.mask, &p.psf, &p.gain],
        )?;
        let f = out[0][0];
        let g = out[1].clone();
        Ok((f, g))
    }

    /// Negated-ELBO value+grad+Hessian over all epochs (Newton payload).
    pub fn neg_elbo_vgh(&self, theta: &[f64], patches: &[Patch]) -> Result<(f64, Vec<f64>, Mat)> {
        let (kf, kg, kh) = self.kl_vgh(theta)?;
        let mut f = kf;
        let mut g = kg;
        let mut h = kh;
        for p in patches {
            let (lf, lg, lh) = self.like_vgh(theta, p)?;
            f -= lf;
            for (gi, li) in g.iter_mut().zip(&lg) {
                *gi -= li;
            }
            for (hi, li) in h.data.iter_mut().zip(&lh.data) {
                *hi -= li;
            }
        }
        h.symmetrize();
        Ok((f, g, h))
    }

    /// Negated-ELBO value+grad over all epochs, selectable engine.
    pub fn neg_elbo_vg(
        &self,
        theta: &[f64],
        patches: &[Patch],
        engine: LikeEngine,
    ) -> Result<(f64, Vec<f64>)> {
        let (kf, kg, _) = self.kl_vgh(theta)?;
        let mut f = kf;
        let mut g = kg;
        for p in patches {
            let (lf, lg) = match engine {
                LikeEngine::PallasManual => self.like_vg_pallas(theta, p)?,
                LikeEngine::AutoDiff => {
                    let (a, b, _) = self.like_vgh(theta, p)?;
                    (a, b)
                }
            };
            f -= lf;
            for (gi, li) in g.iter_mut().zip(&lg) {
                *gi -= li;
            }
        }
        Ok((f, g))
    }

    /// Execute the standalone Pallas renderer (parity tests, benches).
    pub fn render_pallas(&self, comps: &[f64]) -> Result<Vec<f64>> {
        let out = self.rt.execute(L::ART_RENDER, &[comps])?;
        Ok(out.into_iter().next().unwrap())
    }
}

fn unpack_vgh(out: &[Vec<f64>]) -> (f64, Vec<f64>, Mat) {
    let f = out[0][0];
    let g = out[1].clone();
    let h = Mat::from_flat(D, D, &out[2]);
    (f, g, h)
}
