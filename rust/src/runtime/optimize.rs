//! The per-source optimization driver: trust-region Newton plus a
//! star/galaxy type-flip refinement.
//!
//! q(a_s) enters the ELBO through sigmoid(θ₀); once the optimizer pushes
//! γ to a saturated extreme, ∂L/∂θ₀ ∝ γ(1−γ) vanishes and the wrong type
//! can be a flat local optimum even when the other type's ELBO is
//! strictly better. The remedy (mirroring Celeste practice of comparing
//! per-type fits) is deterministic: after convergence, re-run the fit
//! with the indicator flipped and keep whichever final ELBO wins.

use crate::model::layout as L;
use crate::model::sigmoid;
use crate::optim::{newton_tr_split, NewtonConfig, OptimResult, SplitConfig};

use crate::imaging::Patch;

use super::elbo::ElboEngine;
use super::objective::SourceObjective;

/// Saturation threshold beyond which the flip check runs.
const GAMMA_SAT: f64 = 0.98;
/// Logit magnitude used for the flipped restart.
const FLIP_LOGIT: f64 = 6.0;

#[derive(Clone, Debug)]
pub struct SourceFit {
    pub theta: [f64; L::DIM],
    pub result: OptimResult,
    /// whether the saturated-γ flip refinement was attempted
    pub flip_tried: bool,
    /// whether the flipped fit won
    pub flip_won: bool,
    /// total artifact-objective evaluations across both fits
    pub total_evals: usize,
}

/// Optimize one source: split-evaluation Newton-TR (cheap Pallas
/// value+grad for trials, autodiff Hessian on accepted points only —
/// EXPERIMENTS.md §Perf), then the type-flip refinement.
pub fn optimize_source(
    engine: &ElboEngine,
    patches: &[Patch],
    theta0: &[f64; L::DIM],
    cfg: &NewtonConfig,
) -> SourceFit {
    let split = SplitConfig { base: cfg.clone(), ..Default::default() };
    let mut obj = SourceObjective::new(engine, patches)
        .with_engine(crate::runtime::elbo::LikeEngine::PallasManual);
    let (res1, h1) = newton_tr_split(&mut obj, theta0.as_slice(), &split);
    let mut total_evals = res1.f_evals + h1;

    let gamma = sigmoid(res1.x[L::I_A]);
    let saturated = !(1.0 - GAMMA_SAT..=GAMMA_SAT).contains(&gamma);
    if !saturated || !res1.converged() {
        let mut theta = [0.0; L::DIM];
        theta.copy_from_slice(&res1.x);
        return SourceFit { theta, result: res1, flip_tried: false, flip_won: false, total_evals };
    }

    // Flipped restart: opposite type, with the *fitted* branch's
    // flux/color factors copied into the newly-active branch. (The
    // inactive branch drifts to the prior during the first fit — only
    // its KL term pulls on it — so flipping the indicator alone starts
    // the comparison from an unfit branch and γ races straight back.)
    let split2 = SplitConfig { base: cfg.clone(), ..Default::default() };
    let mut t2 = res1.x.clone();
    let galaxy_won_first = gamma > 0.5;
    t2[L::I_A] = if galaxy_won_first { -FLIP_LOGIT } else { FLIP_LOGIT };
    let (src, dst) = if galaxy_won_first {
        (L::I_FLUX_GAL, L::I_FLUX_STAR)
    } else {
        (L::I_FLUX_STAR, L::I_FLUX_GAL)
    };
    t2[dst] = res1.x[src];
    t2[dst + 1] = res1.x[src + 1];
    let (csrc, cdst, vsrc, vdst) = if galaxy_won_first {
        (L::I_COLOR_MEAN_GAL, L::I_COLOR_MEAN_STAR, L::I_COLOR_VAR_GAL, L::I_COLOR_VAR_STAR)
    } else {
        (L::I_COLOR_MEAN_STAR, L::I_COLOR_MEAN_GAL, L::I_COLOR_VAR_STAR, L::I_COLOR_VAR_GAL)
    };
    for i in 0..L::N_COLORS {
        t2[cdst + i] = res1.x[csrc + i];
        t2[vdst + i] = res1.x[vsrc + i];
    }
    let mut obj2 = SourceObjective::new(engine, patches)
        .with_engine(crate::runtime::elbo::LikeEngine::PallasManual);
    let (res2, h2) = newton_tr_split(&mut obj2, &t2, &split2);
    total_evals += res2.f_evals + h2;

    let flip_won = res2.converged() && res2.f < res1.f;
    let best = if flip_won { res2 } else { res1 };
    let mut theta = [0.0; L::DIM];
    theta.copy_from_slice(&best.x);
    SourceFit { theta, result: best, flip_tried: true, flip_won, total_evals }
}
