//! Celeste-rs: scalable Bayesian inference for astronomical catalogs.
//!
//! Reproduction of "Learning an Astronomical Catalog of the Visible
//! Universe through Scalable Bayesian Inference" (CS.DC 2016) as a
//! three-layer Rust + JAX + Pallas system. See DESIGN.md.
pub mod benchkit;
pub mod catalog;
pub mod cli;
pub mod coordinator;
pub mod cluster;
pub mod dtree;
pub mod experiments;
pub mod fits;
pub mod imaging;
pub mod ga;
pub mod jsonlite;
pub mod linalg;
pub mod metrics;
pub mod model;
pub mod prng;
pub mod quickcheck;
pub mod optim;
pub mod photo;
pub mod runtime;
pub mod serve;
pub mod sky;
