//! Catalogs: entries, spatial (Hilbert-curve) ordering, and neighbor
//! search.
//!
//! The paper's phase 2 loads "an existing catalog of candidate light
//! sources ... ordered according to their spatial position, thus nearby
//! light sources are also close together in the global array" (§III-D).
//! The Hilbert order implemented here is exactly that: contiguous task
//! ranges become spatially compact, so Dtree batches have high image
//! locality.

mod hilbert;

pub use hilbert::{hilbert_d2xy, hilbert_sky_key, hilbert_xy2d};

use crate::model::{GalaxyShape, SourceParams};
use crate::prng::Rng;

/// One catalog row: a point estimate of a candidate light source.
#[derive(Clone, Debug)]
pub struct CatalogEntry {
    pub id: usize,
    pub pos: (f64, f64),
    pub p_gal: f64,
    pub flux_r: f64,
    pub colors: [f64; 4],
    pub shape: GalaxyShape,
}

impl CatalogEntry {
    pub fn to_source(&self) -> SourceParams {
        SourceParams {
            pos: self.pos,
            is_galaxy: self.p_gal > 0.5,
            flux_r: self.flux_r,
            colors: self.colors,
            shape: self.shape,
        }
    }
}

/// A catalog plus its spatial index.
#[derive(Clone, Debug)]
pub struct Catalog {
    pub entries: Vec<CatalogEntry>,
    /// sky extent (for the grid index)
    pub width: f64,
    pub height: f64,
    grid: Grid,
}

#[derive(Clone, Debug)]
struct Grid {
    cell: f64,
    nx: usize,
    ny: usize,
    cells: Vec<Vec<usize>>,
}

impl Grid {
    fn build(entries: &[CatalogEntry], width: f64, height: f64, cell: f64) -> Grid {
        let nx = (width / cell).ceil().max(1.0) as usize;
        let ny = (height / cell).ceil().max(1.0) as usize;
        let mut cells = vec![Vec::new(); nx * ny];
        for (i, e) in entries.iter().enumerate() {
            let cx = ((e.pos.0 / cell) as usize).min(nx - 1);
            let cy = ((e.pos.1 / cell) as usize).min(ny - 1);
            cells[cy * nx + cx].push(i);
        }
        Grid { cell, nx, ny, cells }
    }
}

impl Catalog {
    /// Build a catalog (indexes by a grid with `cell` pixel cells).
    pub fn new(mut entries: Vec<CatalogEntry>, width: f64, height: f64) -> Catalog {
        // spatial (Hilbert) ordering — paper §III-D phase 2
        hilbert::sort_hilbert(&mut entries, width, height);
        for (i, e) in entries.iter_mut().enumerate() {
            e.id = i;
        }
        let grid = Grid::build(&entries, width, height, 64.0);
        Catalog { entries, width, height, grid }
    }

    pub fn len(&self) -> usize {
        self.entries.len()
    }

    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }

    /// Indices of entries within `radius` of `pos`, excluding `exclude`.
    pub fn neighbors_within(&self, pos: (f64, f64), radius: f64, exclude: usize) -> Vec<usize> {
        let g = &self.grid;
        let r_cells = (radius / g.cell).ceil() as isize + 1;
        let cx = (pos.0 / g.cell) as isize;
        let cy = (pos.1 / g.cell) as isize;
        let mut out = Vec::new();
        for dy in -r_cells..=r_cells {
            for dx in -r_cells..=r_cells {
                let (x, y) = (cx + dx, cy + dy);
                if x < 0 || y < 0 || x >= g.nx as isize || y >= g.ny as isize {
                    continue;
                }
                for &i in &g.cells[y as usize * g.nx + x as usize] {
                    if i == exclude {
                        continue;
                    }
                    let e = &self.entries[i];
                    let d2 = (e.pos.0 - pos.0).powi(2) + (e.pos.1 - pos.1).powi(2);
                    if d2 <= radius * radius {
                        out.push(i);
                    }
                }
            }
        }
        out.sort_unstable();
        out
    }

    /// Mean distance between consecutive entries — measures the locality
    /// of the task ordering (lower = better scheduler batches).
    pub fn ordering_locality(&self) -> f64 {
        if self.entries.len() < 2 {
            return 0.0;
        }
        let mut total = 0.0;
        for w in self.entries.windows(2) {
            total += ((w[0].pos.0 - w[1].pos.0).powi(2) + (w[0].pos.1 - w[1].pos.1).powi(2)).sqrt();
        }
        total / (self.entries.len() - 1) as f64
    }
}

/// Simulate a "previous survey" catalog: the ground truth perturbed by
/// estimation noise (the initializations the paper's phase 2 loads).
pub fn noisy_catalog(
    sources: &[SourceParams],
    width: f64,
    height: f64,
    rng: &mut Rng,
    pos_sd: f64,
    flux_rel_sd: f64,
) -> Catalog {
    let entries = sources
        .iter()
        .enumerate()
        .map(|(i, s)| {
            let mut colors = s.colors;
            for c in &mut colors {
                *c += rng.normal() * 0.15;
            }
            // misclassify ~15% of sources in the init
            let p_gal = if rng.uniform() < 0.15 {
                if s.is_galaxy { 0.3 } else { 0.7 }
            } else if s.is_galaxy {
                0.75
            } else {
                0.25
            };
            CatalogEntry {
                id: i,
                pos: (
                    s.pos.0 + rng.normal() * pos_sd,
                    s.pos.1 + rng.normal() * pos_sd,
                ),
                p_gal,
                flux_r: (s.flux_r * (1.0 + rng.normal() * flux_rel_sd)).max(0.5),
                colors,
                shape: GalaxyShape {
                    p_dev: (s.shape.p_dev + rng.normal() * 0.1).clamp(0.05, 0.95),
                    axis_ratio: (s.shape.axis_ratio + rng.normal() * 0.1).clamp(0.1, 0.95),
                    angle: s.shape.angle + rng.normal() * 0.2,
                    scale: (s.shape.scale * (1.0 + rng.normal() * 0.2)).clamp(0.3, 8.0),
                },
            }
        })
        .collect();
    Catalog::new(entries, width, height)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sky::{generate, SkyConfig};

    fn demo_catalog(n: usize) -> Catalog {
        let u = generate(&SkyConfig { n_sources: n, ..Default::default() });
        let mut rng = Rng::new(9);
        noisy_catalog(&u.sources, u.width, u.height, &mut rng, 0.5, 0.2)
    }

    #[test]
    fn ids_are_sequential_after_ordering() {
        let c = demo_catalog(200);
        for (i, e) in c.entries.iter().enumerate() {
            assert_eq!(e.id, i);
        }
    }

    #[test]
    fn neighbors_within_matches_bruteforce() {
        let c = demo_catalog(400);
        let radius = 40.0;
        for probe in [0usize, 17, 399] {
            let pos = c.entries[probe].pos;
            let got = c.neighbors_within(pos, radius, probe);
            let mut want: Vec<usize> = c
                .entries
                .iter()
                .enumerate()
                .filter(|(i, e)| {
                    *i != probe
                        && ((e.pos.0 - pos.0).powi(2) + (e.pos.1 - pos.1).powi(2))
                            <= radius * radius
                })
                .map(|(i, _)| i)
                .collect();
            want.sort_unstable();
            assert_eq!(got, want, "probe {probe}");
        }
    }

    #[test]
    fn hilbert_ordering_improves_locality() {
        let u = generate(&SkyConfig { n_sources: 2000, ..Default::default() });
        let mut rng = Rng::new(4);
        let ordered = noisy_catalog(&u.sources, u.width, u.height, &mut rng, 0.5, 0.2);
        // compare with a random-order catalog (bypass ::new's sort)
        let mut shuffled = ordered.entries.clone();
        rng.shuffle(&mut shuffled);
        let mut dist = 0.0;
        for w in shuffled.windows(2) {
            dist +=
                ((w[0].pos.0 - w[1].pos.0).powi(2) + (w[0].pos.1 - w[1].pos.1).powi(2)).sqrt();
        }
        let random_locality = dist / (shuffled.len() - 1) as f64;
        assert!(
            ordered.ordering_locality() < 0.25 * random_locality,
            "hilbert {} vs random {}",
            ordered.ordering_locality(),
            random_locality
        );
    }

    #[test]
    fn noisy_catalog_is_near_truth() {
        let u = generate(&SkyConfig { n_sources: 300, ..Default::default() });
        let mut rng = Rng::new(2);
        let c = noisy_catalog(&u.sources, u.width, u.height, &mut rng, 0.5, 0.2);
        assert_eq!(c.len(), 300);
        // every entry is within a few px of some true source
        for e in &c.entries {
            let dmin = u
                .sources
                .iter()
                .map(|s| ((s.pos.0 - e.pos.0).powi(2) + (s.pos.1 - e.pos.1).powi(2)).sqrt())
                .fold(f64::MAX, f64::min);
            assert!(dmin < 5.0, "entry too far from truth: {dmin}");
        }
    }
}
