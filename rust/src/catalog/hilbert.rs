//! Hilbert space-filling curve for spatial task ordering.

use super::CatalogEntry;

/// Order of the curve used for sorting (2^16 cells per axis).
const ORDER: u32 = 16;

/// Map (x, y) on a 2^order x 2^order grid to its Hilbert-curve distance.
pub fn hilbert_xy2d(order: u32, mut x: u32, mut y: u32) -> u64 {
    let mut rx: u32;
    let mut ry: u32;
    let mut d: u64 = 0;
    let mut s: u32 = 1 << (order - 1);
    while s > 0 {
        rx = u32::from((x & s) > 0);
        ry = u32::from((y & s) > 0);
        d += (s as u64) * (s as u64) * ((3 * rx) ^ ry) as u64;
        // rotate quadrant
        if ry == 0 {
            if rx == 1 {
                x = s.wrapping_sub(1).wrapping_sub(x) & (s.wrapping_mul(2).wrapping_sub(1));
                y = s.wrapping_sub(1).wrapping_sub(y) & (s.wrapping_mul(2).wrapping_sub(1));
            }
            std::mem::swap(&mut x, &mut y);
        }
        s /= 2;
    }
    d
}

/// Inverse map: Hilbert distance to (x, y).
pub fn hilbert_d2xy(order: u32, d: u64) -> (u32, u32) {
    let (mut x, mut y) = (0u32, 0u32);
    let mut t = d;
    let mut s: u64 = 1;
    while s < (1u64 << order) {
        let rx = (1 & (t / 2)) as u32;
        let ry = (1 & (t ^ rx as u64)) as u32;
        // rotate
        if ry == 0 {
            if rx == 1 {
                x = (s as u32 - 1).wrapping_sub(x);
                y = (s as u32 - 1).wrapping_sub(y);
            }
            std::mem::swap(&mut x, &mut y);
        }
        x += (s as u32) * rx;
        y += (s as u32) * ry;
        t /= 4;
        s *= 2;
    }
    (x, y)
}

/// Hilbert key of a sky position over a `width` x `height` extent,
/// quantized to the ORDER-bit curve. This is the ordering key used both
/// for catalog task ordering and for `serve::Store` shard assignment, so
/// inference batches and serving shards share the same spatial locality.
pub fn hilbert_sky_key(pos: (f64, f64), width: f64, height: f64) -> u64 {
    let n = (1u32 << ORDER) as f64;
    let x = ((pos.0 / width) * n).clamp(0.0, n - 1.0) as u32;
    let y = ((pos.1 / height) * n).clamp(0.0, n - 1.0) as u32;
    hilbert_xy2d(ORDER, x, y)
}

/// Sort catalog entries along the Hilbert curve over the sky extent.
pub fn sort_hilbert(entries: &mut [CatalogEntry], width: f64, height: f64) {
    entries.sort_by_key(|e| hilbert_sky_key(e.pos, width, height));
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn xy2d_d2xy_roundtrip() {
        for order in [2u32, 4, 8] {
            let n = 1u32 << order;
            for x in (0..n).step_by(3) {
                for y in (0..n).step_by(3) {
                    let d = hilbert_xy2d(order, x, y);
                    assert_eq!(hilbert_d2xy(order, d), (x, y), "order {order} ({x},{y})");
                }
            }
        }
    }

    #[test]
    fn curve_is_bijective_order3() {
        let order = 3;
        let n = 1u64 << order;
        let mut seen = vec![false; (n * n) as usize];
        for x in 0..n as u32 {
            for y in 0..n as u32 {
                let d = hilbert_xy2d(order, x, y) as usize;
                assert!(!seen[d], "duplicate d {d}");
                seen[d] = true;
            }
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn consecutive_d_are_adjacent_cells() {
        let order = 5;
        let n = 1u64 << order;
        let mut prev = hilbert_d2xy(order, 0);
        for d in 1..(n * n) {
            let cur = hilbert_d2xy(order, d);
            let dist = (cur.0 as i64 - prev.0 as i64).abs() + (cur.1 as i64 - prev.1 as i64).abs();
            assert_eq!(dist, 1, "jump at d={d}: {prev:?} -> {cur:?}");
            prev = cur;
        }
    }
}
