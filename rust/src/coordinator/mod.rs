//! The real (non-simulated) inference coordinator: the paper's three
//! phases (§III-D) executed against the compiled artifacts.
//!
//! 1. Load images (FITS-lite dir or in-memory fields) into the shared
//!    image store (the single-host stand-in for the global array).
//! 2. Load the candidate catalog (spatially ordered).
//! 3. Optimize sources: worker threads pull contiguous batches from a
//!    shared Dtree, render neighbors into patch backgrounds, and run
//!    trust-region Newton per source. Each worker owns a PJRT `Runtime`
//!    (the client is not `Send`), mirroring the paper's
//!    process-with-threads structure.

use std::path::Path;
use std::sync::Mutex;

use anyhow::Result;

use crate::catalog::Catalog;
use crate::dtree::{Dtree, DtreeConfig};
use crate::imaging::{extract_patch, FieldImages, Patch, Survey};
use crate::metrics::{Breakdown, Component, Stats, Stopwatch};
use crate::model::layout as L;
use crate::model::{extract_estimate, theta_init, Estimate, Prior, SourceParams};
use crate::optim::NewtonConfig;
use crate::runtime::{optimize_source, ElboEngine, Runtime};

#[derive(Clone, Debug)]
pub struct InferenceConfig {
    pub threads: usize,
    pub newton: NewtonConfig,
    /// neighbor rendering radius, px
    pub neighbor_radius: f64,
    /// skip patches covering less than this fraction of valid pixels
    pub min_coverage: f64,
    pub dtree: DtreeConfig,
    /// artifact directory
    pub artifact_dir: std::path::PathBuf,
}

impl Default for InferenceConfig {
    fn default() -> Self {
        InferenceConfig {
            threads: 1,
            newton: NewtonConfig::default(),
            neighbor_radius: 20.0,
            min_coverage: 0.3,
            dtree: DtreeConfig::default(),
            artifact_dir: crate::runtime::default_artifact_dir(),
        }
    }
}

/// One inferred catalog row, with the posterior uncertainties that
/// distinguish Celeste from heuristic pipelines.
#[derive(Clone, Debug)]
pub struct InferredSource {
    pub id: usize,
    /// absolute fitted position
    pub pos: (f64, f64),
    pub est: Estimate,
    /// posterior SD of log flux (type-marginalized)
    pub flux_logsd: f64,
    /// posterior SDs of the four colors
    pub color_sd: [f64; L::N_COLORS],
    pub elbo: f64,
    pub iterations: usize,
    pub converged: bool,
    pub flipped: bool,
    pub n_epochs: usize,
}

/// Aggregate statistics of an inference run.
#[derive(Clone, Debug, Default)]
pub struct RunStats {
    pub wall_secs: f64,
    pub sources: usize,
    pub converged: usize,
    pub iters: Stats,
    pub evals: Stats,
    pub sources_per_sec: f64,
    pub breakdown: Breakdown,
}

/// Extract posterior uncertainties from θ.
fn uncertainties(t: &[f64; L::DIM]) -> (f64, [f64; L::N_COLORS]) {
    let g = crate::model::sigmoid(t[L::I_A]);
    let vs = t[L::I_FLUX_STAR + 1].exp();
    let vg = t[L::I_FLUX_GAL + 1].exp();
    let flux_logsd = ((1.0 - g) * vs + g * vg).sqrt();
    let mut csd = [0.0; L::N_COLORS];
    for i in 0..L::N_COLORS {
        let vs = t[L::I_COLOR_VAR_STAR + i].exp();
        let vg = t[L::I_COLOR_VAR_GAL + i].exp();
        csd[i] = ((1.0 - g) * vs + g * vg).sqrt();
    }
    (flux_logsd, csd)
}

/// Run inference over all catalog entries. `fields` are the survey's
/// rendered (or loaded) exposures.
pub fn run_inference(
    fields: &[FieldImages],
    catalog: &Catalog,
    prior: &Prior,
    cfg: &InferenceConfig,
) -> Result<(Vec<InferredSource>, RunStats)> {
    let sw = Stopwatch::start();
    let n = catalog.len();
    let dtree = Mutex::new(Dtree::new(cfg.dtree.clone(), cfg.threads.max(1), n));
    let results: Mutex<Vec<Option<InferredSource>>> = Mutex::new(vec![None; n]);
    let breakdown = Mutex::new(Breakdown::new());
    let iters = Mutex::new(Stats::new());
    let evals = Mutex::new(Stats::new());

    std::thread::scope(|scope| -> Result<()> {
        let mut handles = Vec::new();
        for worker in 0..cfg.threads.max(1) {
            let (dtree, results, breakdown, iters, evals) =
                (&dtree, &results, &breakdown, &iters, &evals);
            handles.push(scope.spawn(move || -> Result<()> {
                // each worker owns its PJRT runtime (client is not Send)
                let rt = Runtime::load_subset(
                    &cfg.artifact_dir,
                    &[L::ART_LIKE_AD, L::ART_LIKE_PALLAS, L::ART_KL],
                )?;
                let engine = ElboEngine::new(&rt, prior);
                // accumulate worker-locally; merge into the shared state
                // once at exit (four global mutex hits per *run*, not per
                // source — the contention fix for many-thread runs)
                let mut local_breakdown = Breakdown::new();
                let mut local_iters = Stats::new();
                let mut local_evals = Stats::new();
                let mut local_results: Vec<(usize, InferredSource)> = Vec::new();
                loop {
                    let grant = dtree.lock().unwrap().request(worker);
                    let Some(grant) = grant else { break };
                    for idx in grant.range.first..grant.range.last {
                        let t_all = Stopwatch::start();
                        let entry = &catalog.entries[idx];
                        // neighbors at their catalog estimates
                        let neighbors: Vec<SourceParams> = catalog
                            .neighbors_within(entry.pos, cfg.neighbor_radius, idx)
                            .into_iter()
                            .map(|j| catalog.entries[j].to_source())
                            .collect();
                        // patches from every exposure containing the source
                        let mut patches: Vec<Patch> = Vec::new();
                        for f in fields {
                            if let Some(p) = extract_patch(f, entry.pos, &neighbors) {
                                if p.coverage >= cfg.min_coverage {
                                    patches.push(p);
                                }
                            }
                        }
                        local_breakdown.add(Component::GaFetch, t_all.elapsed_secs());
                        if patches.is_empty() {
                            continue;
                        }
                        let t_opt = Stopwatch::start();
                        let t0 = theta_init(&entry.to_source(), entry.p_gal);
                        let fit = optimize_source(&engine, &patches, &t0, &cfg.newton);
                        local_breakdown.add(Component::Optimize, t_opt.elapsed_secs());

                        let est = extract_estimate(&fit.theta);
                        let (flux_logsd, color_sd) = uncertainties(&fit.theta);
                        let pr = patches[0].rect;
                        let pos = (
                            pr.x0 + L::PATCH as f64 / 2.0 + est.d_pos.0,
                            pr.y0 + L::PATCH as f64 / 2.0 + est.d_pos.1,
                        );
                        local_iters.push(fit.result.iterations as f64);
                        local_evals.push(fit.total_evals as f64);
                        local_results.push((
                            idx,
                            InferredSource {
                                id: entry.id,
                                pos,
                                est,
                                flux_logsd,
                                color_sd,
                                elbo: -fit.result.f,
                                iterations: fit.result.iterations,
                                converged: fit.result.converged(),
                                flipped: fit.flip_won,
                                n_epochs: patches.len(),
                            },
                        ));
                    }
                }
                breakdown.lock().unwrap().merge(&local_breakdown);
                iters.lock().unwrap().merge(&local_iters);
                evals.lock().unwrap().merge(&local_evals);
                {
                    let mut all = results.lock().unwrap();
                    for (idx, src) in local_results {
                        all[idx] = Some(src);
                    }
                }
                Ok(())
            }));
        }
        for h in handles {
            h.join().expect("worker panicked")?;
        }
        Ok(())
    })?;

    let out: Vec<InferredSource> = results.into_inner().unwrap().into_iter().flatten().collect();
    let wall = sw.elapsed_secs();
    let stats = RunStats {
        wall_secs: wall,
        sources: out.len(),
        converged: out.iter().filter(|s| s.converged).count(),
        iters: iters.into_inner().unwrap(),
        evals: evals.into_inner().unwrap(),
        sources_per_sec: out.len() as f64 / wall.max(1e-9),
        breakdown: breakdown.into_inner().unwrap(),
    };
    Ok((out, stats))
}

/// Load every field found in a FITS-lite directory.
pub fn load_fields_dir(dir: &Path) -> Result<Vec<FieldImages>> {
    let mut ids = std::collections::BTreeSet::new();
    for entry in std::fs::read_dir(dir)? {
        let name = entry?.file_name();
        let name = name.to_string_lossy().to_string();
        if let Some(rest) = name.strip_prefix("field-") {
            if let Some(idx) = rest.split("-band-").next() {
                if let Ok(id) = idx.parse::<usize>() {
                    ids.insert(id);
                }
            }
        }
    }
    let mut out = Vec::new();
    for id in ids {
        out.push(crate::fits::read_field(dir, id)?);
    }
    Ok(out)
}

/// Render a survey in memory (the generate step without disk I/O).
pub fn render_survey(
    survey: &Survey,
    sources: &[SourceParams],
    seed: u64,
) -> Vec<FieldImages> {
    let mut rng = crate::prng::Rng::new(seed);
    survey
        .fields
        .iter()
        .map(|g| crate::imaging::render_field(sources, g, &mut rng))
        .collect()
}
