//! Minimal command-line parsing (the offline registry has no clap).

use std::collections::BTreeMap;

/// Parsed command line: subcommand, flags (--key value / --key), args.
#[derive(Clone, Debug, Default)]
pub struct Cli {
    pub command: String,
    pub flags: BTreeMap<String, String>,
    pub positional: Vec<String>,
}

impl Cli {
    pub fn parse(args: impl IntoIterator<Item = String>) -> Cli {
        let mut it = args.into_iter().peekable();
        let mut cli = Cli::default();
        if let Some(cmd) = it.next() {
            cli.command = cmd;
        }
        while let Some(a) = it.next() {
            if let Some(key) = a.strip_prefix("--") {
                // --key=value, or --key value, or bare boolean --key
                if let Some((k, v)) = key.split_once('=') {
                    cli.flags.insert(k.to_string(), v.to_string());
                    continue;
                }
                let val = match it.peek() {
                    Some(v) if !v.starts_with("--") => it.next().unwrap(),
                    _ => "true".to_string(),
                };
                cli.flags.insert(key.to_string(), val);
            } else {
                cli.positional.push(a);
            }
        }
        cli
    }

    pub fn from_env() -> Cli {
        Cli::parse(std::env::args().skip(1))
    }

    pub fn flag(&self, key: &str) -> Option<&str> {
        self.flags.get(key).map(String::as_str)
    }

    pub fn flag_bool(&self, key: &str) -> bool {
        matches!(self.flag(key), Some("true") | Some("1") | Some("yes"))
    }

    pub fn flag_usize(&self, key: &str, default: usize) -> usize {
        self.flag_parse(key, default)
    }

    pub fn flag_f64(&self, key: &str, default: f64) -> f64 {
        self.flag_parse(key, default)
    }

    pub fn flag_u64(&self, key: &str, default: u64) -> u64 {
        self.flag_parse(key, default)
    }

    pub fn flag_str<'a>(&'a self, key: &str, default: &'a str) -> &'a str {
        self.flag(key).unwrap_or(default)
    }

    /// Parse a flag as any `FromStr` type (the typed helpers above are
    /// thin wrappers over this). Unparseable values fall back to the
    /// default, matching the pre-existing CLI behavior.
    pub fn flag_parse<T: std::str::FromStr>(&self, key: &str, default: T) -> T {
        self.flag(key).and_then(|v| v.parse().ok()).unwrap_or(default)
    }

    /// Parse a count-valued flag with validation: absent uses
    /// `default`; present must parse as an integer and be at least
    /// `min`. Unlike [`flag_parse`](Cli::flag_parse) — whose silent
    /// fall-back-to-default turns `--threads -3` or `--shards x` into
    /// a quietly different run — degenerate values (zero where a
    /// positive count is required, negative, or non-numeric) are a
    /// clear error naming the flag.
    pub fn flag_count(&self, key: &str, default: usize, min: usize) -> Result<usize, String> {
        match self.flag(key) {
            None => Ok(default),
            Some(raw) => match raw.parse::<usize>() {
                Ok(v) if v >= min => Ok(v),
                Ok(v) => Err(format!("--{key} must be at least {min}, got {v}")),
                Err(_) => Err(format!(
                    "--{key} must be a non-negative integer, got {raw:?}"
                )),
            },
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cli(s: &str) -> Cli {
        Cli::parse(s.split_whitespace().map(String::from))
    }

    #[test]
    fn parses_command_flags_positional() {
        let c = cli("infer --threads 4 data.bin extra --quick");
        assert_eq!(c.command, "infer");
        assert_eq!(c.flag_usize("threads", 1), 4);
        assert!(c.flag_bool("quick"));
        assert_eq!(c.positional, vec!["data.bin", "extra"]);
        // --key=value is unambiguous before positionals
        let c2 = cli("infer --quick=true data.bin");
        assert!(c2.flag_bool("quick"));
        assert_eq!(c2.positional, vec!["data.bin"]);
    }

    #[test]
    fn defaults_apply() {
        let c = cli("run");
        assert_eq!(c.flag_usize("threads", 2), 2);
        assert_eq!(c.flag_f64("radius", 1.5), 1.5);
        assert!(!c.flag_bool("quick"));
        assert_eq!(c.flag_str("engine", "ad"), "ad");
    }

    #[test]
    fn flag_parse_generic() {
        let c = cli("serve-bench --qps 1500 --shards 8 --bad x");
        assert_eq!(c.flag_parse("qps", 0.0f64), 1500.0);
        assert_eq!(c.flag_parse("shards", 1u32), 8);
        assert_eq!(c.flag_parse("bad", 7i64), 7); // unparseable -> default
        assert_eq!(c.flag_parse("missing", 3usize), 3);
    }

    #[test]
    fn empty_args() {
        let c = Cli::parse(std::iter::empty());
        assert_eq!(c.command, "");
    }

    #[test]
    fn flag_count_accepts_valid_and_absent() {
        let c = cli("serve-bench --threads 4 --dist-nodes 0");
        assert_eq!(c.flag_count("threads", 1, 1), Ok(4));
        assert_eq!(c.flag_count("missing", 8, 1), Ok(8));
        // zero is legal when the floor allows it (--dist-nodes 0 = tier off)
        assert_eq!(c.flag_count("dist-nodes", 0, 0), Ok(0));
    }

    #[test]
    fn flag_count_rejects_degenerate_values_with_a_clear_error() {
        // note: "-3" is consumed as the flag's value by the parser, and
        // flag_parse would silently fall back to the default — exactly
        // the quiet misconfiguration flag_count exists to reject
        let c = cli("serve-bench --threads -3 --shards 0 --replicas x --burst 1.5");
        let err = c.flag_count("threads", 4, 1).unwrap_err();
        assert!(err.contains("--threads") && err.contains("-3"), "{err}");
        let err = c.flag_count("shards", 8, 1).unwrap_err();
        assert!(err.contains("--shards") && err.contains("at least 1"), "{err}");
        let err = c.flag_count("replicas", 1, 1).unwrap_err();
        assert!(err.contains("--replicas"), "{err}");
        let err = c.flag_count("burst", 1, 1).unwrap_err();
        assert!(err.contains("--burst"), "{err}");
    }
}
