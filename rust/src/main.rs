//! celeste — the launcher.
//!
//! Subcommands:
//!   smoke                              PJRT + artifact sanity check
//!   generate  --out DIR [...]          synthesize a survey to FITS-lite
//!   infer     --data DIR [...]         run Bayesian inference (phases 1-3)
//!   photo     --data DIR [--coadd]     run the heuristic baseline
//!   serve-bench [...]                  benchmark the catalog serving path
//!   recover-bench [...]                measure WAL crash-recovery time (RTO)
//!   shard-server --snapshot F [...]    serve one catalog partition over TCP
//!   experiment NAME [--quick] [...]    regenerate a paper table/figure
//!       NAME ∈ fig1 | fig3 | fig4 | fig5 | fig6 | table1 | newton-vs-lbfgs | all

use anyhow::{bail, Result};

use celeste::catalog::noisy_catalog;
use celeste::cli::Cli;
use celeste::coordinator::{load_fields_dir, run_inference, InferenceConfig};
use celeste::experiments;
use celeste::imaging::{Survey, SurveyConfig};
use celeste::jsonlite::Value;
use celeste::model::Prior;
use celeste::photo::{coadd, run_photo, PhotoConfig};
use celeste::prng::Rng;
use celeste::serve;
use celeste::sky::{generate, SkyConfig};

fn main() -> Result<()> {
    let cli = Cli::from_env();
    match cli.command.as_str() {
        "smoke" => cmd_smoke(),
        "generate" => cmd_generate(&cli),
        "infer" => cmd_infer(&cli),
        "photo" => cmd_photo(&cli),
        "serve-bench" => cmd_serve_bench(&cli),
        "recover-bench" => cmd_recover_bench(&cli),
        "shard-server" => cmd_shard_server(&cli),
        "experiment" => cmd_experiment(&cli),
        "" | "help" | "--help" => {
            print!("{}", HELP);
            Ok(())
        }
        other => bail!("unknown command {other:?}; see `celeste help`"),
    }
}

const HELP: &str = "\
celeste — scalable Bayesian inference for astronomical catalogs

USAGE: celeste <command> [flags]

  smoke                            check PJRT and compiled artifacts
  generate --out DIR               synthesize a survey
           [--sources N] [--epochs E] [--seed S] [--width W] [--height H]
  infer    --data DIR              run inference over a generated survey
           [--threads N] [--out FILE] [--snapshot FILE]
           (--snapshot also writes a serve snapshot for serve-bench)
  photo    --data DIR [--coadd]    run the heuristic baseline pipeline
           [--snapshot F]  also write the detections as a serve
                           snapshot (servable via serve-bench)
  serve-bench                      benchmark the sharded catalog server
           [--threads N]   server worker threads        (default 4)
           [--sched S]     request scheduler: condvar | steal
                           (default condvar; steal = per-worker FIFO
                           deques + randomized oldest-first stealing)
           [--batch N]     jobs a worker drains and executes per
                           wake-up (default 1); same-shard queries in a
                           batch share one pass over the shard list
           [--burst B]     open-loop arrivals per burst (default 1 =
                           plain Poisson; rate is unchanged)
           [--shards K]    Hilbert-range shards         (default 8)
           [--qps Q]       open-loop offered rate       (default 2000)
           [--mix M]       uniform | hotspot | xmatch | drift, or explicit
                           weights 'cone=6,box=3,brightest=1,xmatch=1'
           [--secs S]      seconds per phase            (default 3)
           [--sources N]   synthetic catalog size       (default 5000)
           [--snapshot F]  serve a snapshot written by `infer` or
                           `photo` instead of a synthetic catalog
           [--seed S]
           Engine middleware layers (echoed before the run):
           [--cache N]     LRU entries per query class  (default 512, 0=off;
                           hits need synchronous completions: dist tier)
           [--hedge-ms B]  replica hedge budget, ms     (dist tier, default off)
           [--hedge-budget F] max fraction of requests hedged (default
                            0.05 when --hedge-ms is set; 0 = uncapped)
           [--queue-depth D] admission bound, single-host (default 1024)
           Live ingestion (mixed read/write; pairs with --mix drift):
           [--ingest-qps R]   delta publishes per second (default 0=off);
                              runs a quiesced phase then an ingesting
                              phase and compares read p99 + hit rate
           [--ingest-batch B] upserts per publish         (default 32)
           [--consistency C]  cached | fresh | atmost:K — consistency
                              stamped on the driven query stream
           Durability (docs/DURABILITY.md; requires --ingest-qps):
           [--wal-dir D]      append+fsync every publish to a durable
                              log in D before its epoch becomes
                              visible; D must be empty. On the tcp
                              transport each server gets D/node-i and
                              acks only after its local fsync; a node
                              killed by --kill-node is restarted from
                              its WAL and checked for byte parity
                              ('recovered_epoch=E parity=ok')
           [--checkpoint-every N] snapshot checkpoint cadence, epochs
                              (default 8; 0 = never; only shards the
                              window touched are rewritten)
           [--compact-threshold T] single-host tier: when max/mean
                              shard-row skew stays above T (> 1.0) for
                              3 consecutive publishes, re-split hot
                              Hilbert ranges and merge cold ones
                              (logged + replayable as a WAL record);
                              skews the drift stream onto a hotspot
           Runs an open-loop (Poisson) phase at --qps, then closed-loop
           throughput at 1 vs --threads workers; prints accepted/shed
           counts and per-class p50/p99 latency.
           Distributed tier (simulated time) when --dist-nodes is set
           (contradicts --threads: exactly one of the two):
           [--dist-nodes N] place shard replicas on N modeled nodes
           [--replicas R]   copies of each shard range   (default 2,
                            must not exceed --dist-nodes)
           [--routing P]    random | rr | p2c            (default p2c)
           [--kill-node S]  fault spec 'NODE@T' (kill) or 'NODE@T1:T2'
                            (kill+revive), comma-separated, sim seconds
           Adaptive control plane (docs/CONTROL.md):
           [--rebalance MS] run a controller that closes a decision
                            window every MS ms: detect the hottest node
                            from windowed per-node load and migrate its
                            hottest shard replicas to the coolest
                            members (minimal-move rendezvous target);
                            in-flight queries keep succeeding during
                            migration. Works on both distributed tiers
                            (sim and tcp); decisions land in the
                            --obs-dump 'control' section
           [--autoscale L..H] let the controller grow/retire membership
                            inside the band (sim tier only; requires
                            --rebalance; the band must bracket
                            --dist-nodes and hold --replicas)
           [--priority-mix L:N:H] stamp each request Low/Normal/High by
                            these weights and grade admission by
                            (priority, cost): under overload the
                            cheap+urgent survive, expensive+background
                            shed first (any tier)
           [--load-curve P:K] swell the offered rate by a raised-cosine
                            curve with period P seconds peaking at K x
                            the base --qps — the diurnal/spiky shape
                            the autoscaler reacts to (any tier)
           --qps/--secs then drive a simulated-time open loop through
           the fabric-attached router; prints per-class p50/p99,
           per-node load imbalance, bytes moved, failover record,
           router-cache hit rate, hedge counts, and (with --ingest-qps)
           epochs shipped, cache invalidations, and stale-replica
           refusals.
           Real-socket transport (multi-process, wall clock):
           [--transport T] sim | tcp (default sim). tcp spawns
                           --dist-nodes local shard-server child
                           processes and serves the same query stream
                           over the length-prefixed binary wire
                           protocol (docs/WIRE.md); --routing and
                           --hedge-ms/--hedge-budget stay sim-only,
                           --kill-node kills the real child process
                           (revive specs are rejected), and ingest
                           publishes ship over the wire to every
                           server before the front-end epoch advances
           [--pipeline N]  tcp only: Execute frames each connection
                           keeps in flight (default 1 = lockstep);
                           replies are matched by req_id, so depth > 1
                           overlaps request transmit with server work
           Observability (docs/OBSERVABILITY.md):
           [--obs-dump F]  write a jsonlite metrics + trace dump at
                           exit (schema celeste-obs-dump-v3). On the
                           tcp transport this also scrapes every live
                           shard server's registry over the wire
                           (StatsReq) and runs a stale-consistency
                           probe whose refusal must round-trip
           [--collect-ms N] continuous telemetry: close a rollup
                           window every N ms (per-window counter
                           deltas, gauge folds, exact p50/p99),
                           scraping every node each window — live
                           servers over the wire on tcp, modeled
                           nodes on sim. Adds per-node + cluster
                           timelines, health verdicts with
                           hysteresis, and SLO burn-rate events to
                           the dump's 'timeline' section; a node
                           killed by --kill-node shows up as gapped
                           windows and an unhealthy transition
           [--trace-sample N] keep every Nth request's per-stage span
                           breakdown (distributed tiers; requires
                           --dist-nodes)
           [--slow-ms T]   slow-query log: keep and print every request
                           slower than T ms with its span breakdown
                           (distributed tiers; sim tier thresholds are
                           in simulated milliseconds)
  recover-bench                    measure WAL recovery time (RTO)
           [--publishes P] epochs to ingest before the simulated crash
                           (default 200)
           [--sources N] [--shards K] [--ingest-batch B] [--seed S]
           [--checkpoint-every N] checkpoint cadence      (default 32)
           [--compact-threshold T] also exercise compaction records
           [--wal-dir D]   log under D (default: a temp dir, removed
                           on success); must be empty
           [--obs-dump F]  write the write-side WAL registry merged
                           with the recovery registry (recovered_epoch
                           and recovery_*_ms gauges, wal_fsync_s) as a
                           celeste-obs-dump-v3 file
           Ingests P epochs through a durable log, drops the store,
           recovers from disk, and prints the RTO split into
           checkpoint-load vs tail-replay plus 'parity: ok' when the
           recovered catalog hashes identically to the write-side
           mirror.
  shard-server --snapshot F        serve one catalog partition over TCP
           [--shards K]    shard count (default 8; must match the
                           front-end's --shards)
           [--listen A]    bind address (default 127.0.0.1:0); prints
                           'shard-server listening on ADDR' when ready
           [--wal-dir D]   fsync every accepted publish to a WAL in D
                           before acking. If D already holds a
                           checkpoint the server recovers from it
                           (no --snapshot needed) and prints
                           'shard-server recovered epoch=E ...' before
                           the listening line
           [--checkpoint-every N] checkpoint cadence      (default 8)
           On SIGTERM the server exits gracefully: it flushes a final
           fsynced checkpoint (when --wal-dir is set) and prints a
           'shard-server terminated ...' status line before exiting
  experiment NAME [--quick]        regenerate a paper table/figure:
           fig1 fig3 fig4 fig5 fig6 ablations table1 newton-vs-lbfgs all
";

fn cmd_smoke() -> Result<()> {
    println!("{}", celeste::runtime::pjrt_smoke()?);
    let dir = celeste::runtime::default_artifact_dir();
    match celeste::runtime::Manifest::load(&dir) {
        Ok(m) => println!("manifest ok: {} artifacts in {:?}", m.artifacts.len(), dir),
        Err(e) => println!("manifest NOT ready ({e}); run `make artifacts`"),
    }
    Ok(())
}

fn cmd_generate(cli: &Cli) -> Result<()> {
    let out = std::path::PathBuf::from(cli.flag_str("out", "data"));
    let n = cli.flag_usize("sources", 500);
    let epochs = cli.flag_usize("epochs", 2);
    let seed = cli.flag_u64("seed", 42);
    let width = cli.flag_f64("width", 1024.0);
    let height = cli.flag_f64("height", 680.0);

    let sky = generate(&SkyConfig { width, height, n_sources: n, seed, ..Default::default() });
    let survey = Survey::layout(SurveyConfig {
        sky_width: width,
        sky_height: height,
        n_epochs: epochs,
        seed: seed ^ 0xa5,
        ..Default::default()
    });
    let mut rng = Rng::new(seed ^ 0x5a);
    std::fs::create_dir_all(&out)?;
    for geom in &survey.fields {
        let field = celeste::imaging::render_field(&sky.sources, geom, &mut rng);
        celeste::fits::write_field(&out, &field)?;
    }
    // write the truth + a noisy init catalog
    let mut rng2 = Rng::new(seed ^ 0x77);
    let catalog = noisy_catalog(&sky.sources, width, height, &mut rng2, 0.7, 0.25);
    let truth_json = catalog_truth_json(&sky.sources);
    std::fs::write(out.join("truth.json"), celeste::jsonlite::to_string(&truth_json))?;
    let init_json = catalog_init_json(&catalog);
    std::fs::write(out.join("catalog.json"), celeste::jsonlite::to_string(&init_json))?;
    println!(
        "generated {} fields x 5 bands, {} sources -> {:?}",
        survey.fields.len(),
        n,
        out
    );
    Ok(())
}

fn catalog_truth_json(sources: &[celeste::model::SourceParams]) -> Value {
    Value::Arr(
        sources
            .iter()
            .map(|s| {
                experiments::obj_pub(vec![
                    ("x", Value::Num(s.pos.0)),
                    ("y", Value::Num(s.pos.1)),
                    ("is_galaxy", Value::Bool(s.is_galaxy)),
                    ("flux_r", Value::Num(s.flux_r)),
                    ("scale", Value::Num(s.shape.scale)),
                ])
            })
            .collect(),
    )
}

fn catalog_init_json(catalog: &celeste::catalog::Catalog) -> Value {
    Value::Arr(
        catalog
            .entries
            .iter()
            .map(|e| {
                experiments::obj_pub(vec![
                    ("id", Value::Num(e.id as f64)),
                    ("x", Value::Num(e.pos.0)),
                    ("y", Value::Num(e.pos.1)),
                    ("p_gal", Value::Num(e.p_gal)),
                    ("flux_r", Value::Num(e.flux_r)),
                ])
            })
            .collect(),
    )
}

fn cmd_infer(cli: &Cli) -> Result<()> {
    let data = std::path::PathBuf::from(cli.flag_str("data", "data"));
    let threads = cli.flag_usize("threads", 1);
    let out = cli.flag_str("out", "catalog_out.json");

    let fields = load_fields_dir(&data)?;
    if fields.is_empty() {
        bail!("no fields in {data:?}; run `celeste generate` first");
    }
    // reconstruct the init catalog from catalog.json
    let cat_text = std::fs::read_to_string(data.join("catalog.json"))?;
    let cat_v = celeste::jsonlite::parse(&cat_text).map_err(|e| anyhow::anyhow!("{e}"))?;
    let (mut width, mut height) = (0.0f64, 0.0f64);
    for f in &fields {
        width = width.max(f.geom.rect.x0 + f.geom.rect.cols as f64);
        height = height.max(f.geom.rect.y0 + f.geom.rect.rows as f64);
    }
    let entries: Vec<celeste::catalog::CatalogEntry> = cat_v
        .as_arr()
        .unwrap_or(&[])
        .iter()
        .enumerate()
        .map(|(i, e)| celeste::catalog::CatalogEntry {
            id: i,
            pos: (
                e.get("x").and_then(Value::as_f64).unwrap_or(0.0),
                e.get("y").and_then(Value::as_f64).unwrap_or(0.0),
            ),
            p_gal: e.get("p_gal").and_then(Value::as_f64).unwrap_or(0.5),
            flux_r: e.get("flux_r").and_then(Value::as_f64).unwrap_or(100.0),
            colors: [0.4, 0.3, 0.2, 0.1],
            shape: celeste::model::GalaxyShape::point_like(),
        })
        .collect();
    let catalog = celeste::catalog::Catalog::new(entries, width, height);
    let prior = Prior::default();
    let cfg = InferenceConfig { threads, ..Default::default() };
    println!(
        "inferring {} sources over {} exposures with {} thread(s)...",
        catalog.len(),
        fields.len(),
        threads
    );
    let (inferred, stats) = run_inference(&fields, &catalog, &prior, &cfg)?;
    println!(
        "done: {} sources, {}/{} converged, {:.2} src/s (mean {:.1} Newton iters)",
        stats.sources,
        stats.converged,
        stats.sources,
        stats.sources_per_sec,
        stats.iters.mean()
    );
    let rows: Vec<Value> = inferred
        .iter()
        .map(|s| {
            experiments::obj_pub(vec![
                ("id", Value::Num(s.id as f64)),
                ("x", Value::Num(s.pos.0)),
                ("y", Value::Num(s.pos.1)),
                ("p_gal", Value::Num(s.est.p_gal)),
                ("flux_r", Value::Num(s.est.flux_r)),
                ("flux_logsd", Value::Num(s.flux_logsd)),
                ("scale", Value::Num(s.est.shape.scale)),
                ("elbo", Value::Num(s.elbo)),
                ("iterations", Value::Num(s.iterations as f64)),
                ("converged", Value::Bool(s.converged)),
            ])
        })
        .collect();
    std::fs::write(out, celeste::jsonlite::to_string(&Value::Arr(rows)))?;
    println!("wrote {out}");
    if let Some(snap_path) = cli.flag("snapshot") {
        let served: Vec<serve::ServedSource> =
            inferred.iter().map(serve::ServedSource::from_inferred).collect();
        serve::snapshot::save_sources(std::path::Path::new(snap_path), &served, width, height)?;
        println!("wrote serve snapshot {snap_path} ({} sources)", served.len());
    }
    Ok(())
}

fn loadgen_config(mix: &str, seed: u64) -> Result<serve::LoadGenConfig> {
    if let Some(cfg) = serve::LoadGenConfig::scenario(mix, seed) {
        return Ok(cfg);
    }
    match serve::QueryMix::parse(mix) {
        Some(m) => Ok(serve::LoadGenConfig { mix: m, seed, ..Default::default() }),
        None => {
            bail!("bad --mix {mix:?}: want uniform|hotspot|xmatch|drift or 'cone=6,box=3,...'")
        }
    }
}

/// Parse `--consistency cached|fresh|atmost:K` into the stamp applied
/// to the driven query stream (None: leave the envelope default).
fn parse_consistency(cli: &Cli) -> Result<Option<serve::Consistency>> {
    let Some(s) = cli.flag("consistency") else { return Ok(None) };
    match s {
        "cached" => Ok(Some(serve::Consistency::CachedOk)),
        "fresh" => Ok(Some(serve::Consistency::Fresh)),
        other => match other.strip_prefix("atmost:").and_then(|k| k.parse::<u32>().ok()) {
            Some(k) => Ok(Some(serve::Consistency::AtMost(k))),
            None => bail!("bad --consistency {other:?}: want cached|fresh|atmost:K"),
        },
    }
}

/// Build the ingestion driver for one bench phase: a drift stream
/// seeded from the versioned store's current catalog, publishing
/// through it at `ingest_qps` publishes/second. `hotspot` > 0 skews
/// fresh detections onto one blob (the compaction trigger's diet).
fn make_ingest_driver(
    versioned: &std::sync::Arc<serve::VersionedStore>,
    ingest_qps: f64,
    batch: usize,
    seed: u64,
    hotspot: f64,
) -> serve::IngestDriver {
    let view = versioned.load();
    let drift = serve::DriftGen::new(
        &view.store.all_sources(),
        view.store.width,
        view.store.height,
        serve::DriftConfig { batch, hotspot, seed: seed ^ 0xd21f, ..Default::default() },
    );
    let ingestor = serve::Ingestor::new(std::sync::Arc::clone(versioned));
    serve::IngestDriver::new(ingestor, drift, ingest_qps, seed)
}

/// The observability knobs shared by every serve-bench tier.
struct ObsOpts {
    /// `--collect-ms N` converted to seconds (0 = continuous collection off)
    collect_s: f64,
    /// `--obs-dump FILE`: jsonlite metrics + trace dump path
    dump: Option<String>,
    /// `--trace-sample N`: keep every Nth request's spans (0 = off)
    trace_every: u64,
    /// `--slow-ms T` converted to seconds (0 = off)
    slow_s: f64,
}

fn parse_obs(cli: &Cli) -> Result<ObsOpts> {
    let trace_every = cli.flag_count("trace-sample", 0, 1).map_err(anyhow::Error::msg)? as u64;
    let slow_ms = cli.flag_parse("slow-ms", 0.0f64);
    if cli.flag("slow-ms").is_some() && slow_ms <= 0.0 {
        bail!(
            "--slow-ms must be a positive number of milliseconds, got {:?}",
            cli.flag("slow-ms").unwrap()
        );
    }
    let collect_ms = cli.flag_parse("collect-ms", 0.0f64);
    if cli.flag("collect-ms").is_some() && collect_ms <= 0.0 {
        bail!(
            "--collect-ms is the telemetry window width and must be a positive number of \
             milliseconds, got {:?}",
            cli.flag("collect-ms").unwrap()
        );
    }
    Ok(ObsOpts {
        collect_s: collect_ms * 1e-3,
        dump: cli.flag("obs-dump").map(str::to_string),
        trace_every,
        slow_s: slow_ms * 1e-3,
    })
}

/// Build the continuous-telemetry collector for one run: `names[0]` is
/// always the front end ("local"), the rest are the per-node rows.
fn make_collector(window_s: f64, names: Vec<String>) -> serve::Collector {
    let cfg = serve::CollectorConfig { window_s, ..Default::default() };
    serve::Collector::new(cfg, names)
}

/// Print the collector's end-of-run summary: window count, gaps,
/// health transitions (the kill-node visibility lines CI greps), and
/// any SLO burn events.
fn print_collector_summary(c: &serve::Collector) {
    let gaps: u64 = (0..c.names().len()).map(|i| c.node_timeline(i).gaps()).sum();
    println!(
        "timeline: {} window(s) of {:.0} ms, {} gap(s), {} health transition(s), \
         {} slo event(s)",
        c.cluster().len(),
        c.window_s() * 1e3,
        gaps,
        c.transitions().len(),
        c.slo_events().len()
    );
    for t in c.transitions() {
        println!(
            "health: {} {} -> {} at window {} (score {:.2})",
            t.node,
            t.from.name(),
            t.to.name(),
            t.window,
            t.score
        );
    }
    for e in c.slo_events() {
        println!(
            "slo burn: {} window {} fast {:.2}x slow {:.2}x{}",
            e.series,
            e.window,
            e.fast_burn,
            e.slow_burn,
            if e.exact { "" } else { " (approx)" }
        );
    }
}

/// One-line per-stage p99 breakdown from a registry snapshot's
/// `stage_*` histograms, omitting stages that never fired.
fn stage_p99_line(snap: &serve::obs::Snapshot) -> Option<String> {
    let mut parts = Vec::new();
    for stage in serve::obs::STAGES {
        if let Some(s) = snap.histograms.get(&format!("stage_{}", stage.name())) {
            if s.n > 0 {
                parts.push(format!("{}={:.3}ms", stage.name(), s.p99() * 1e3));
            }
        }
    }
    if parts.is_empty() {
        None
    } else {
        Some(format!("stage p99: {}", parts.join(" ")))
    }
}

fn cmd_serve_bench(cli: &Cli) -> Result<()> {
    // every flag is parsed and cross-validated in one place — the full
    // contradiction matrix lives (and is unit-tested) in serve::config
    let cfg = serve::ServeConfig::from_cli(cli).map_err(anyhow::Error::msg)?;
    let count = |key, default, min| cli.flag_count(key, default, min).map_err(anyhow::Error::msg);
    let (shards, qps, secs, seed) = (cfg.shards, cfg.qps, cfg.secs, cfg.seed);
    let (threads, sched) = (cfg.threads, cfg.sched);
    let (spec, mix) = (cfg.spec.clone(), cfg.mix.as_str());

    let snap = match cli.flag("snapshot") {
        Some(path) => serve::snapshot::load(std::path::Path::new(path))?,
        None => serve::snapshot::synthetic(cfg.n_sources, seed),
    };
    let (width, height) = (snap.width, snap.height);
    let store = std::sync::Arc::new(snap.into_store(shards));
    println!("{}", store.summary());
    let mut gen_cfg = loadgen_config(&cfg.mix, seed)?;
    cfg.apply_to_loadgen(&mut gen_cfg);

    // --- distributed tier when --dist-nodes is set: simulated fabric
    //     by default, real shard-server processes with --transport tcp ---
    if cfg.dist() {
        return if cfg.tcp {
            cmd_serve_bench_tcp(cli, &cfg, store, gen_cfg)
        } else {
            cmd_serve_bench_dist(cli, &cfg, store, gen_cfg)
        };
    }
    let consistency = parse_consistency(cli)?;
    let obs = parse_obs(cli)?;
    let ingest_qps = cli.flag_parse("ingest-qps", 0.0f64).max(0.0);
    let ingest_batch = count("ingest-batch", 32, 1)?;
    let wal_dir = cli.flag("wal-dir").map(std::path::PathBuf::from);
    if let Some(dir) = &wal_dir {
        if serve::DurableLog::exists(dir) {
            bail!(
                "--wal-dir {} already holds a checkpoint; point serve-bench at an empty \
                 directory (recover the old log with shard-server or recover-bench)",
                dir.display()
            );
        }
    }
    let checkpoint_every = cli.flag_u64("checkpoint-every", 8);
    let compact_threshold = cli.flag_parse("compact-threshold", 0.0f64);
    if cli.flag("compact-threshold").is_some() && compact_threshold <= 1.0 {
        bail!(
            "--compact-threshold is a max/mean shard-row skew ratio and must exceed 1.0, \
             got {compact_threshold}"
        );
    }
    // the ingesting phase's WAL registry (fsync latencies, appends,
    // checkpoints), merged into the --obs-dump at exit
    let mut wal_snapshot: Option<serve::obs::Snapshot> = None;
    // the single-host tier's unified metrics view: drive + worker-pool
    // reports absorbed per phase, dumped at exit with --obs-dump
    let obs_reg = serve::Registry::new();
    // continuous telemetry (--collect-ms): one "local" node sampled
    // from obs_reg each window. Counters land at phase boundaries (the
    // reports are absorbed at shutdown) but the queue-depth gauge is
    // live; the finish() window picks up the final counter totals so
    // the timeline conserves against the dumped registry exactly.
    let mut collector =
        (obs.collect_s > 0.0).then(|| make_collector(obs.collect_s, vec!["local".to_string()]));
    let collect_t0 = std::time::Instant::now();

    // --- phase 1: open loop (latency + admission control at --qps).
    //     Admission is a middleware layer now; the server's own queue
    //     bound is parked at infinity so the layer is the one shed
    //     point, probing the real queue depth through the engine API.
    //     Note: fire-and-forget submissions queue into the worker pool,
    //     so their results cannot fill the Cached layer — open-loop
    //     cache hits only appear on the simulated tier, where
    //     completions are synchronous.
    //     With --ingest-qps the phase runs twice — quiesced, then with
    //     live publishes flowing through a versioned store — so the
    //     ingestion cost shows up as a p99 delta on the same load ---
    let mut phase_p99: Vec<(String, f64)> = Vec::new();
    for ingesting in [false, true] {
        if ingesting && ingest_qps <= 0.0 {
            continue;
        }
        let versioned = std::sync::Arc::new(serve::VersionedStore::new(store.clone()));
        let server = std::sync::Arc::new(if ingesting {
            serve::Server::start_live(
                std::sync::Arc::clone(&versioned),
                serve::ServerConfig { threads, queue_depth: usize::MAX, sched },
            )
        } else {
            serve::Server::start(
                store.clone(),
                serve::ServerConfig { threads, queue_depth: usize::MAX, sched },
            )
        });
        let mut engine = serve::layered(
            Box::new(serve::ServerEngine::new(std::sync::Arc::clone(&server))),
            &spec,
        );
        if let Some(c) = consistency {
            engine = Box::new(serve::Consistent::new(engine, c));
        }
        if !ingesting {
            println!("engine: {}", engine.describe());
            if spec.cache_entries > 0 {
                println!(
                    "note: open-loop submissions are fire-and-forget, so the cache layer \
                     cannot fill from them; hit-rate measurement lives on the simulated \
                     tier (--dist-nodes)"
                );
            }
        }
        // durable ingestion: create the log over the seed catalog and
        // attach it so every publish is fsynced before becoming visible
        let wal_log = match (&wal_dir, ingesting) {
            (Some(dir), true) => {
                let log = std::sync::Arc::new(serve::DurableLog::create(
                    dir,
                    checkpoint_every,
                    &versioned.load(),
                )?);
                versioned.attach_wal(std::sync::Arc::clone(&log));
                Some(log)
            }
            _ => None,
        };
        // compaction wants skew to react to: point the drift hotspot
        // at one blob so sustained ingestion piles onto a few shards
        let hotspot = if ingesting && compact_threshold > 0.0 { 0.8 } else { 0.0 };
        let mut driver = if ingesting {
            Some(make_ingest_driver(&versioned, ingest_qps, ingest_batch, seed, hotspot))
        } else {
            None
        };
        let mut compactor = (ingesting && compact_threshold > 0.0)
            .then(|| serve::Compactor::new(compact_threshold, 3));
        let mut compactions = 0u64;
        let mut compacted_rows = 0u64;
        let mut gen = serve::LoadGen::new(gen_cfg.clone(), width, height);
        let mut clock = serve::WallClock::start();
        let mut ol = serve::drive_open_loop_with(&engine, &mut clock, &mut gen, qps, secs, |at| {
            if let Some(d) = driver.as_mut() {
                let published = !d.tick(at).is_empty();
                if let Some(c) = compactor.as_mut() {
                    if published && c.observe(&d.ingestor().versioned().load().store) {
                        if let Some(rep) = d.ingestor_mut().compact(compact_threshold) {
                            compactions += 1;
                            compacted_rows += rep.rows_resharded as u64;
                        }
                    }
                }
            }
            if let Some(c) = collector.as_mut() {
                let mut src = |_t: f64| {
                    let mut s = obs_reg.snapshot();
                    s.gauges.insert("queue_depth".to_string(), server.queue_len() as f64);
                    vec![Some(s)]
                };
                c.tick(collect_t0.elapsed().as_secs_f64(), &mut src);
            }
        });
        let report = server.shutdown();
        ol.absorb_server(&report);
        obs_reg.absorb_drive(&ol);
        obs_reg.absorb_server(&report);
        let label = if ingesting { "ingesting" } else { "quiesced" };
        println!(
            "open loop ({mix}, {label}): offered {:.0} qps for {:.1}s",
            ol.offered_qps(),
            ol.arrival_secs
        );
        println!("{}", ol.summary());
        println!("{}", report.summary());
        if let Some(d) = &driver {
            println!(
                "ingest: {} publish(es), {} upsert row(s), head at epoch {}",
                d.publishes,
                d.rows,
                d.ingestor().versioned().epoch()
            );
        }
        if compactions > 0 {
            obs_reg.counter("compactions").add(compactions);
            obs_reg.counter("compaction_moves").add(compacted_rows);
            println!("compaction: {compactions} re-split(s), {compacted_rows} row(s) resharded");
        }
        if let Some(log) = &wal_log {
            let ws = log.obs().snapshot();
            let appends = ws.counters.get("wal_appends").copied().unwrap_or(0);
            let bytes = ws.counters.get("wal_bytes").copied().unwrap_or(0);
            let checkpoints = ws.counters.get("wal_checkpoints").copied().unwrap_or(0);
            match ws.histograms.get("wal_fsync_s") {
                Some(f) if f.n > 0 => println!(
                    "wal: {appends} append(s), {checkpoints} checkpoint(s), {:.2} MB logged, \
                     fsync p50={:.3}ms p99={:.3}ms",
                    bytes as f64 / (1024.0 * 1024.0),
                    f.p50() * 1e3,
                    f.p99() * 1e3
                ),
                _ => println!("wal: {appends} append(s), {checkpoints} checkpoint(s)"),
            }
            wal_snapshot = Some(ws);
        }
        phase_p99.push((label.to_string(), report.latency_all().p99()));
    }
    if phase_p99.len() == 2 {
        println!(
            "read p99 quiesced {:.3}ms vs ingesting {:.3}ms",
            phase_p99[0].1 * 1e3,
            phase_p99[1].1 * 1e3
        );
    }

    // --- phase 2: closed-loop peak throughput, 1 vs --threads workers
    //     (bare tier: no cache layer, so the comparison measures
    //     execution scaling, not memoization) ---
    let clients = threads * 2;
    let mut worker_counts = vec![1];
    if threads > 1 {
        worker_counts.push(threads);
    }
    for &t in &worker_counts {
        let server = std::sync::Arc::new(serve::Server::start(
            store.clone(),
            serve::ServerConfig { threads: t, sched, ..Default::default() },
        ));
        let engine = serve::ServerEngine::new(std::sync::Arc::clone(&server));
        let mut gen = serve::LoadGen::new(gen_cfg.clone(), width, height);
        let cl = serve::drive_closed_loop(&engine, &mut gen, clients, secs);
        let report = server.shutdown();
        obs_reg.absorb_drive(&cl);
        obs_reg.absorb_server(&report);
        let all = cl.latency_all();
        println!(
            "closed loop {t} worker(s), {clients} clients: {:.0} qps (completed {}, shed {}, p50={:.3}ms p99={:.3}ms)",
            cl.qps(),
            cl.completed,
            cl.shed,
            all.p50() * 1e3,
            all.p99() * 1e3
        );
    }
    let snap = match &wal_snapshot {
        Some(ws) => serve::obs::Snapshot::merge_all([&obs_reg.snapshot(), ws]),
        None => obs_reg.snapshot(),
    };
    if let Some(line) = stage_p99_line(&snap) {
        println!("{line}");
    }
    if let Some(c) = collector.as_mut() {
        // final partial window: the closed-loop phases' absorbed
        // counters (and the WAL registry, if one ran) land here, so
        // the timeline's conservation total equals the dumped metrics
        let mut src = |_t: f64| vec![Some(snap.clone())];
        c.finish(collect_t0.elapsed().as_secs_f64(), &mut src);
        print_collector_summary(c);
    }
    if let Some(path) = &obs.dump {
        serve::obs::write_dump(path, &snap, &[], &[], collector.as_ref(), None)?;
        println!("wrote obs dump {path}");
    }
    Ok(())
}

/// The replicated multi-node serving tier, modeled in simulated time:
/// shard replicas placed by rendezvous hashing, sub-queries riding the
/// `ga::Fabric` cost model, replica selection per --routing, optional
/// mid-run node kills per --kill-node — behind the same layered engine
/// stack as the single-host tier (router caching and hedging included).
/// With --ingest-qps the drive runs twice (quiesced, then with delta
/// publishes shipped to the replica tier) and compares read p99 and
/// cache behavior.
fn cmd_serve_bench_dist(
    cli: &Cli,
    cfg: &serve::ServeConfig,
    store: std::sync::Arc<serve::Store>,
    gen_cfg: serve::LoadGenConfig,
) -> Result<()> {
    let (qps, secs, seed) = (cfg.qps, cfg.secs, cfg.seed);
    let spec = &cfg.spec;
    let nodes = cfg.dist_nodes.max(1);
    let replicas = cfg.replicas;
    if replicas > nodes {
        bail!(
            "--replicas {replicas} exceeds --dist-nodes {nodes}: a shard cannot have more \
             replicas than there are nodes to hold them. Lower --replicas or raise \
             --dist-nodes."
        );
    }
    let routing_s = cli.flag_str("routing", "p2c");
    let Some(routing) = serve::dist::Routing::parse(routing_s) else {
        bail!("bad --routing {routing_s:?}: want random|rr|p2c");
    };
    let schedule = match cli.flag("kill-node") {
        Some(kill_spec) => {
            let Some(schedule) = serve::dist::FailureSchedule::parse(kill_spec) else {
                bail!(
                    "bad --kill-node {kill_spec:?}: want 'NODE@T' or 'NODE@T1:T2', comma-separated"
                );
            };
            if let Some(max) = schedule.max_node() {
                if max >= nodes {
                    bail!(
                        "--kill-node names node {max}, but --dist-nodes is {nodes} (ids 0..{})",
                        nodes - 1
                    );
                }
            }
            Some(schedule)
        }
        None => None,
    };
    let consistency = parse_consistency(cli)?;
    let obs = parse_obs(cli)?;
    let ingest_qps = cli.flag_parse("ingest-qps", 0.0f64).max(0.0);
    let ingest_batch = cli.flag_count("ingest-batch", 32, 1).map_err(anyhow::Error::msg)?;
    // the sim tier models backlog as latency, so a uniform admission
    // bound would just re-shed what the queue model absorbs — but the
    // graded bound (--priority-mix) sheds *selectively*, which is the
    // point: keep it, modeling the backlog as outstanding completions
    let dist_spec = serve::LayerSpec {
        admit_depth: if spec.graded_admission { spec.admit_depth } else { 0 },
        ..spec.clone()
    };

    let mut phase_stats: Vec<(String, f64, f64)> = Vec::new();
    let mut obs_snaps: Vec<serve::obs::Snapshot> = Vec::new();
    let mut obs_traces: Vec<serve::TraceRecord> = Vec::new();
    let mut obs_seen = 0u64;
    // each phase builds a fresh router (fresh registries), so the
    // timeline restarts with it: the dump carries the last phase's
    // collector, whose windows conserve against that phase's registry
    let mut collected: Option<serve::Collector> = None;
    // the last phase's control-plane decision log rides into the dump
    let mut ctl_log: Option<serve::DecisionLog> = None;
    // with --autoscale the fabric is built at the band ceiling but the
    // placement starts on the first --dist-nodes members; the
    // controller grows into (or retires from) the headroom
    let capacity = cfg.capacity();
    let members0: Vec<usize> = (0..nodes).collect();
    for ingesting in [false, true] {
        if ingesting && ingest_qps <= 0.0 {
            continue;
        }
        let mut router = serve::dist::Router::new_among(
            std::sync::Arc::clone(&store),
            capacity,
            &members0,
            replicas,
            serve::dist::RouterConfig { routing, seed, ..Default::default() },
        );
        if let Some(s) = &schedule {
            router = router.with_schedule(s.clone());
        }
        if !ingesting {
            println!("{}", router.placement.summary());
        }
        let rengine = serve::RouterEngine::new(router);
        rengine.sampler().configure(obs.trace_every, obs.slow_s);
        let mut engine = serve::layered(Box::new(rengine.clone()), &dist_spec);
        if let Some(c) = consistency {
            engine = Box::new(serve::Consistent::new(engine, c));
        }
        if !ingesting {
            println!("engine: {}", engine.describe());
        }
        let mut driver = if ingesting {
            let versioned =
                std::sync::Arc::new(serve::VersionedStore::new(std::sync::Arc::clone(&store)));
            Some(make_ingest_driver(&versioned, ingest_qps, ingest_batch, seed, 0.0))
        } else {
            None
        };
        let publisher = rengine.clone();
        let mut collector = (obs.collect_s > 0.0).then(|| {
            let mut names = vec!["local".to_string()];
            // one timeline row per fabric slot: node_samples() reports
            // the full capacity, including autoscale headroom
            names.extend((0..capacity).map(|n| format!("node-{n}")));
            make_collector(obs.collect_s, names)
        });
        let scraper = rengine.clone();
        let mut ctl = cfg
            .controller_config()
            .map(|c| serve::Controller::new(c, capacity, &members0));
        let ctl_engine = rengine.clone();
        let mut t_last = 0.0f64;
        let mut gen = serve::LoadGen::new(gen_cfg.clone(), store.width, store.height);
        let mut clock = serve::SimClock::new();
        let drive =
            serve::drive_open_loop_with(&engine, &mut clock, &mut gen, qps, secs, |at| {
                if let Some(d) = driver.as_mut() {
                    for rep in d.tick(at) {
                        publisher.publish(at, &rep);
                    }
                }
                if let Some(c) = collector.as_mut() {
                    t_last = at;
                    let mut src = |t: f64| {
                        let mut v = vec![Some(scraper.registry().snapshot())];
                        v.extend(scraper.node_samples(t));
                        v
                    };
                    c.tick(at, &mut src);
                }
                // the control plane ticks between arrivals against the
                // same router the drive executes on: read windowed
                // load, maybe start live migrations toward its target
                if let Some(c) = ctl.as_mut() {
                    ctl_engine.with_router_mut(|r| {
                        let loads: Vec<serve::NodeLoad> = (0..r.n_nodes())
                            .map(|n| serve::NodeLoad {
                                alive: r.node_alive(n),
                                served: r.served_per_node[n],
                                busy_s: r.busy_per_node[n],
                            })
                            .collect();
                        let shard_served = r.served_per_shard.clone();
                        if let Some(target) = c.tick(at, &loads, &shard_served, &r.placement) {
                            r.rebalance_to(at, &target);
                        }
                    });
                }
            });
        let report = rengine.dist_report(&drive);
        let label = if ingesting { "ingesting" } else { "quiesced" };
        println!("routing {} ({label}):", routing.name());
        println!("{}", report.summary());
        let mut hit_rate = 0.0;
        if dist_spec.cache_entries > 0 {
            let hits = serve::metric(&engine, "cache_hits").unwrap_or(0.0);
            let misses = serve::metric(&engine, "cache_misses").unwrap_or(0.0);
            let invalidations = serve::metric(&engine, "cache_invalidations").unwrap_or(0.0);
            let saved = serve::metric(&engine, "cache_bytes_saved").unwrap_or(0.0);
            hit_rate = if hits + misses > 0.0 { hits / (hits + misses) } else { 0.0 };
            let inv_rate =
                if hits + misses > 0.0 { invalidations / (hits + misses) } else { 0.0 };
            println!(
                "router cache: {:.1}% hit rate ({:.0} hits), {:.1}% invalidated ({:.0} entries \
                 covering mutated ranges), {:.2} MB fabric bytes saved (vs {:.2} MB moved)",
                hit_rate * 100.0,
                hits,
                inv_rate * 100.0,
                invalidations,
                saved / 1e6,
                report.bytes_moved / 1e6
            );
        }
        if drive.hedges > 0 {
            println!("hedges: {} fired, {} won", drive.hedges, drive.hedge_wins);
        }
        if let Some(skipped) = serve::metric(&engine, "hedge_budget_skipped") {
            if skipped > 0.0 {
                println!("hedge budget: {skipped:.0} request(s) past the cap left unhedged");
            }
        }
        if let Some(c) = ctl.take() {
            println!("{}", c.log().summary());
            println!(
                "migrations={}",
                ctl_engine.with_router(|r| r.migrations)
            );
            ctl_log = Some(c.log().clone());
        }
        if let Some(d) = &driver {
            println!(
                "ingest: {} publish(es), {} upsert row(s), {:.2} MB delta shipped",
                d.publishes,
                d.rows,
                report.delta_bytes / 1e6
            );
        }
        phase_stats.push((label.to_string(), report.latency_all().p99(), hit_rate));
        // fold this phase's drive + engine-stack accounting into the
        // tier's registry and keep the snapshot for the merged dump
        rengine.registry().absorb_drive(&drive);
        rengine.registry().absorb_metrics(&engine.metrics());
        let snap = rengine.registry().snapshot();
        if let Some(mut c) = collector.take() {
            // final partial window after the absorbs, so the timeline
            // total equals this phase's dumped registry counters
            let mut src = |t: f64| {
                let mut v = vec![Some(snap.clone())];
                v.extend(scraper.node_samples(t));
                v
            };
            c.finish(t_last, &mut src);
            print_collector_summary(&c);
            collected = Some(c);
        }
        if let Some(line) = stage_p99_line(&snap) {
            println!("{line} (simulated)");
        }
        for line in rengine.sampler().slow_log() {
            println!("{line}");
        }
        obs_snaps.push(snap);
        obs_traces.extend(rengine.sampler().records());
        obs_seen += rengine.sampler().seen();
    }
    if phase_stats.len() == 2 {
        println!(
            "read p99 quiesced {:.3}ms vs ingesting {:.3}ms; hit rate {:.1}% vs {:.1}%",
            phase_stats[0].1 * 1e3,
            phase_stats[1].1 * 1e3,
            phase_stats[0].2 * 100.0,
            phase_stats[1].2 * 100.0
        );
    }
    if obs.trace_every > 0 {
        println!("trace sample: kept {} of {} request(s)", obs_traces.len(), obs_seen);
    }
    if let Some(path) = &obs.dump {
        let merged = serve::obs::Snapshot::merge_all(&obs_snaps);
        serve::obs::write_dump(
            path,
            &merged,
            &[],
            &obs_traces,
            collected.as_ref(),
            ctl_log.as_ref(),
        )?;
        println!("wrote obs dump {path} ({} trace(s))", obs_traces.len());
    }
    Ok(())
}

/// The tcp transport: the same replicated scatter-gather story as the
/// simulated tier, but measured instead of modeled — real shard-server
/// child processes, real sockets, real serialization, driven on the
/// wall clock. `--kill-node` kills the actual child process mid-run;
/// with replication R the run absorbs up to R-1 deaths with zero
/// failed queries. This wrapper owns the child processes and the
/// snapshot temp file so every exit path (including errors mid-spawn)
/// reaps and removes them.
fn cmd_serve_bench_tcp(
    cli: &Cli,
    cfg: &serve::ServeConfig,
    store: std::sync::Arc<serve::Store>,
    gen_cfg: serve::LoadGenConfig,
) -> Result<()> {
    let snap_path =
        std::env::temp_dir().join(format!("celeste-serve-{}.json", std::process::id()));
    serve::snapshot::save(&snap_path, &store)?;
    let mut children: Vec<std::process::Child> = Vec::new();
    let result = drive_serve_tcp(cli, cfg, store, gen_cfg, &snap_path, &mut children);
    // --kill-node may have killed some already; reap everything either way
    for child in &mut children {
        let _ = child.kill();
        let _ = child.wait();
    }
    std::fs::remove_file(&snap_path).ok();
    result
}

fn drive_serve_tcp(
    cli: &Cli,
    cfg: &serve::ServeConfig,
    store: std::sync::Arc<serve::Store>,
    gen_cfg: serve::LoadGenConfig,
    snap_path: &std::path::Path,
    children: &mut Vec<std::process::Child>,
) -> Result<()> {
    let (shards, qps, secs, seed) = (cfg.shards, cfg.qps, cfg.secs, cfg.seed);
    let spec = &cfg.spec;
    let nodes = cfg.dist_nodes.max(1);
    let replicas = cfg.replicas;
    if replicas > nodes {
        bail!(
            "--replicas {replicas} exceeds --dist-nodes {nodes}: a shard cannot have more \
             replicas than there are shard servers to hold them. Lower --replicas or raise \
             --dist-nodes."
        );
    }
    let schedule = match cli.flag("kill-node") {
        Some(kill_spec) => {
            let Some(schedule) = serve::dist::FailureSchedule::parse(kill_spec) else {
                bail!("bad --kill-node {kill_spec:?}: want 'NODE@T', comma-separated");
            };
            if schedule.has_revive() {
                bail!(
                    "--kill-node revive specs (NODE@T1:T2) only apply to the simulated tier: \
                     a killed shard-server process cannot be restarted mid-run"
                );
            }
            if let Some(max) = schedule.max_node() {
                if max >= nodes {
                    bail!(
                        "--kill-node names node {max}, but --dist-nodes is {nodes} (ids 0..{})",
                        nodes - 1
                    );
                }
            }
            Some(schedule)
        }
        None => None,
    };
    let consistency = parse_consistency(cli)?;
    let ingest_qps = cli.flag_parse("ingest-qps", 0.0f64).max(0.0);
    let ingest_batch = cli.flag_count("ingest-batch", 32, 1).map_err(anyhow::Error::msg)?;
    let pipeline = cli.flag_count("pipeline", 1, 1).map_err(anyhow::Error::msg)?;
    let wal_dir = cli.flag("wal-dir").map(std::path::PathBuf::from);
    if let Some(dir) = &wal_dir {
        for node in 0..nodes {
            let node_dir = dir.join(format!("node-{node}"));
            if serve::DurableLog::exists(&node_dir) {
                bail!(
                    "--wal-dir {} already holds a checkpoint under {}; point the bench at \
                     an empty directory",
                    dir.display(),
                    node_dir.display()
                );
            }
        }
    }
    let checkpoint_every = cli.flag_u64("checkpoint-every", 8);
    // same stack shape as the sim tier: cache + hedge-free layers over
    // the router, no uniform admission bound (the sockets backpressure
    // instead) — but --priority-mix keeps the graded bound, which sheds
    // selectively by (priority, class) rather than re-shedding backlog
    let dist_spec = serve::LayerSpec {
        admit_depth: if spec.graded_admission { spec.admit_depth } else { 0 },
        ..spec.clone()
    };

    // every shard server loads the snapshot and builds an identical
    // store, so shard indices agree across the process boundary; with
    // --wal-dir each server fsyncs its publishes under its own node dir
    let exe = std::env::current_exe()?;
    let mut addrs: Vec<String> = Vec::new();
    let mut readers: Vec<std::io::BufReader<std::process::ChildStdout>> = Vec::new();
    for node in 0..nodes {
        let mut cmd = std::process::Command::new(&exe);
        cmd.arg("shard-server")
            .arg("--snapshot")
            .arg(snap_path)
            .args(["--shards", &shards.to_string(), "--listen", "127.0.0.1:0"]);
        if let Some(dir) = &wal_dir {
            cmd.arg("--wal-dir").arg(dir.join(format!("node-{node}")));
            cmd.args(["--checkpoint-every", &checkpoint_every.to_string()]);
        }
        let mut child = cmd.stdout(std::process::Stdio::piped()).spawn()?;
        let stdout = child.stdout.take().expect("stdout is piped");
        children.push(child);
        let (addr, _, reader) = read_shard_server_announce(stdout)?;
        addrs.push(addr);
        readers.push(reader);
    }

    let net = serve::NetRouterEngine::connect_pipelined(
        std::sync::Arc::clone(&store),
        &addrs,
        replicas,
        pipeline,
    )?;
    let obs = parse_obs(cli)?;
    net.configure_tracing(obs.trace_every, obs.slow_s);
    println!("{}", net.placement().summary());
    let mut engine = serve::layered(Box::new(net.clone()), &dist_spec);
    if let Some(c) = consistency {
        engine = Box::new(serve::Consistent::new(engine, c));
    }
    println!("engine: {}", engine.describe());
    println!("spawned {nodes} shard-server process(es), {shards} shards x{replicas} replicas");

    let mut driver = if ingest_qps > 0.0 {
        let versioned =
            std::sync::Arc::new(serve::VersionedStore::new(std::sync::Arc::clone(&store)));
        let mut d = make_ingest_driver(&versioned, ingest_qps, ingest_batch, seed, 0.0);
        if wal_dir.is_some() {
            // remember the mirror's checksum at every epoch so the
            // crash-recovery drill can verify parity at *whatever*
            // epoch the killed server durably reached
            d.track_checksums();
        }
        Some(d)
    } else {
        None
    };
    let events: Vec<serve::dist::FailureEvent> =
        schedule.map(|s| s.events().to_vec()).unwrap_or_default();
    let mut next_event = 0;
    let publisher = net.clone();
    // continuous telemetry (--collect-ms): the front end is node
    // "local", each shard server a "server-N" row scraped over the
    // wire every window. A dead server's failed scrape marks its
    // connection suspected, so later windows gap instantly.
    let mut collector = (obs.collect_s > 0.0).then(|| {
        let mut names = vec!["local".to_string()];
        names.extend((0..nodes).map(|n| format!("server-{n}")));
        make_collector(obs.collect_s, names)
    });
    let scraper = net.clone();
    // the control plane on the tcp tier: same controller, but a
    // migration is an instant routing swap (every server holds the
    // full catalog) and membership is fixed (--autoscale is sim-only)
    let members0: Vec<usize> = (0..nodes).collect();
    let mut ctl = cfg
        .controller_config()
        .map(|c| serve::Controller::new(c, nodes, &members0));
    let ctl_net = net.clone();
    let mut t_last = 0.0f64;
    let mut gen = serve::LoadGen::new(gen_cfg, store.width, store.height);
    let mut clock = serve::WallClock::start();
    let drive = serve::drive_open_loop_with(&engine, &mut clock, &mut gen, qps, secs, |at| {
        while next_event < events.len() && events[next_event].at <= at {
            let ev = events[next_event];
            next_event += 1;
            if let Some(child) = children.get_mut(ev.node) {
                let _ = child.kill();
                println!("killed shard-server {} at t={:.2}s", ev.node, at);
            }
        }
        if let Some(d) = driver.as_mut() {
            for rep in d.tick(at) {
                publisher.publish(&rep);
            }
        }
        if let Some(c) = collector.as_mut() {
            t_last = at;
            let mut src = |_t: f64| {
                let mut v = vec![Some(scraper.obs_snapshot())];
                v.extend(scraper.scrape_nodes(std::time::Duration::from_millis(300)));
                v
            };
            c.tick(at, &mut src);
        }
        if let Some(c) = ctl.as_mut() {
            let loads = ctl_net.node_loads();
            let shard_served = ctl_net.served_per_shard();
            let placement = ctl_net.placement();
            if let Some(target) = c.tick(at, &loads, &shard_served, &placement) {
                match ctl_net.rebalance_to(target) {
                    Ok(moved) => {
                        println!("rebalanced {moved} shard replica set(s) at t={at:.2}s")
                    }
                    Err(e) => println!("rebalance skipped at t={at:.2}s: {e}"),
                }
            }
        }
    });

    println!(
        "tcp transport: offered {:.0} qps for {:.1}s over {nodes} server(s)",
        drive.offered_qps(),
        drive.arrival_secs
    );
    println!("{}", drive.summary());
    let m: std::collections::BTreeMap<String, f64> = net.metrics().into_iter().collect();
    println!(
        "wire: {:.0} frame(s), {:.3} MB sent, {:.3} MB recv, {:.0} reconnect(s), \
         {:.0} timeout(s), {:.0} io error(s), {:.0} failover(s), {:.0} stale refusal(s), \
         encode {:.1}us decode {:.1}us per frame",
        m["net_frames"],
        m["net_bytes_sent"] / 1e6,
        m["net_bytes_recv"] / 1e6,
        m["net_reconnects"],
        m["net_timeouts"],
        m["net_io_errors"],
        m["net_failovers"],
        m["net_stale_refusals"],
        m["net_encode_us_per_frame"],
        m["net_decode_us_per_frame"]
    );
    if let Some(line) = stage_p99_line(&net.registry().snapshot()) {
        println!("{line}");
    }
    let mut ctl_log: Option<serve::DecisionLog> = None;
    if let Some(c) = ctl.take() {
        println!("{}", c.log().summary());
        println!("migrations={}", net.migrations());
        ctl_log = Some(c.log().clone());
    }
    if let Some(d) = &driver {
        println!(
            "ingest: {} publish(es) shipped to every live server, head at epoch {}",
            d.publishes,
            d.ingestor().versioned().epoch()
        );
    }
    if obs.trace_every > 0 {
        println!(
            "trace sample: kept {} of {} request(s)",
            net.sampler().records().len(),
            net.sampler().seen()
        );
    }
    for line in net.sampler().slow_log() {
        println!("{line}");
    }
    // fold the drive's disposition counters in before the collector's
    // final window, so the timeline's conservation total matches the
    // dumped registry exactly
    net.registry().absorb_drive(&drive);
    if let Some(c) = collector.as_mut() {
        let mut src = |_t: f64| {
            let mut v = vec![Some(scraper.obs_snapshot())];
            v.extend(scraper.scrape_nodes(std::time::Duration::from_millis(300)));
            v
        };
        c.finish(t_last, &mut src);
    }
    // crash-recovery drill: when the run was durable and --kill-node
    // took a server down mid-publish, restart it from its WAL alone
    // (no --snapshot) and check byte parity at whatever epoch it
    // durably acked. The CI smoke greps 'recovered_epoch=.* parity=ok'.
    let mut recovered_snaps: Vec<serve::obs::Snapshot> = Vec::new();
    if let (Some(dir), Some(ev)) = (&wal_dir, events.first()) {
        let node_dir = dir.join(format!("node-{}", ev.node));
        let mut cmd = std::process::Command::new(&exe);
        cmd.arg("shard-server")
            .args(["--shards", &shards.to_string(), "--listen", "127.0.0.1:0"])
            .arg("--wal-dir")
            .arg(&node_dir);
        let mut child = cmd.stdout(std::process::Stdio::piped()).spawn()?;
        let stdout = child.stdout.take().expect("stdout is piped");
        children.push(child);
        let (addr, recovered, reader) = read_shard_server_announce(stdout)?;
        readers.push(reader);
        let line = recovered.ok_or_else(|| {
            anyhow::anyhow!("restarted shard-server did not report a WAL recovery")
        })?;
        println!("{line}");
        let field = |key: &str| {
            line.split_whitespace()
                .find_map(|w| w.strip_prefix(&format!("{key}=")).map(str::to_string))
                .ok_or_else(|| anyhow::anyhow!("recovery line missing {key}= (got {line:?})"))
        };
        let epoch: u64 = field("epoch")?.parse()?;
        let checksum = u64::from_str_radix(&field("checksum")?, 16)?;
        let want = driver.as_ref().and_then(|d| d.checksum_at(epoch));
        if want == Some(checksum) {
            println!("recovered_epoch={epoch} parity=ok");
        } else {
            println!("recovered_epoch={epoch} parity=MISMATCH");
            bail!(
                "crash recovery parity failed: server hashed {checksum:016x} at epoch \
                 {epoch}, write-side mirror has {:?}",
                want.map(|w| format!("{w:016x}"))
            );
        }
        // fold the restarted server back into the telemetry: its
        // scrape (registry + WAL recovery gauges: recovered_epoch,
        // recovery_*_ms) opens a `recovered` window on its timeline
        // and flips the health verdict back without hysteresis
        match serve::net::scrape_addr(&addr, std::time::Duration::from_millis(500)) {
            Ok(s) => {
                if let Some(c) = collector.as_mut() {
                    c.record_recovery(ev.node + 1, s.clone());
                }
                recovered_snaps.push(s);
            }
            Err(e) => println!("restarted shard-server scrape failed: {e}"),
        }
    }
    if let Some(c) = &collector {
        print_collector_summary(c);
    }
    if let Some(path) = &obs.dump {
        // the probe proves the stale-refusal path end to end: the
        // server must refuse a bound one past the head, incrementing
        // its counter and ours, both of which land in the dump below
        let refused = net.probe_stale();
        println!("stale probe: refused={refused}");
        let metrics = net.obs_snapshot();
        let mut servers = net.scrape();
        servers.extend(recovered_snaps);
        let traces = net.sampler().records();
        serve::obs::write_dump(
            path,
            &metrics,
            &servers,
            &traces,
            collector.as_ref(),
            ctl_log.as_ref(),
        )?;
        println!(
            "wrote obs dump {path} ({} server snapshot(s), {} trace(s))",
            servers.len(),
            traces.len()
        );
    }
    // graceful-shutdown drill: SIGTERM every surviving server. Each
    // polls the flag in its accept loop, flushes (a final fsynced
    // checkpoint under --wal-dir), prints its terminal status line —
    // forwarded here, CI greps 'shard-server terminated' — and exits.
    let mut terminated = 0usize;
    for (child, reader) in children.iter_mut().zip(readers.iter_mut()) {
        if child.try_wait()?.is_some() {
            continue; // killed by --kill-node, already reaped below
        }
        if !serve::net::signal::send_term(child.id()) {
            continue; // undeliverable: the hard-kill backstop reaps it
        }
        use std::io::BufRead;
        let mut line = String::new();
        loop {
            line.clear();
            if reader.read_line(&mut line)? == 0 {
                break;
            }
            let trimmed = line.trim();
            if trimmed.starts_with("shard-server terminated") {
                println!("{trimmed}");
                terminated += 1;
                break;
            }
        }
        let _ = child.wait();
    }
    if terminated > 0 {
        println!("graceful shutdown: {terminated} server(s) flushed and exited");
    }
    // the CI smoke greps this exact line: replication must absorb the
    // scheduled kills with nothing lost
    println!("failed_queries={}", m["net_failed"] as u64);
    Ok(())
}

/// Read a freshly spawned shard-server's announce lines: an optional
/// 'shard-server recovered ...' report, then
/// 'shard-server listening on ADDR'. Returns the address, the
/// recovery line (if one was printed), and the reader itself — the
/// parent keeps it open so the child's terminal status line after a
/// graceful SIGTERM can be read back (and so the child's stdout pipe
/// never closes under it mid-print).
fn read_shard_server_announce(
    stdout: std::process::ChildStdout,
) -> Result<(String, Option<String>, std::io::BufReader<std::process::ChildStdout>)> {
    use std::io::BufRead;
    let mut reader = std::io::BufReader::new(stdout);
    let mut recovered = None;
    for _ in 0..16 {
        let mut line = String::new();
        if reader.read_line(&mut line)? == 0 {
            break;
        }
        let line = line.trim();
        if line.contains("listening on") {
            let addr = line.rsplit(' ').next().filter(|a| a.contains(':')).ok_or_else(|| {
                anyhow::anyhow!("shard-server announced no address (got {line:?})")
            })?;
            return Ok((addr.to_string(), recovered, reader));
        }
        if line.starts_with("shard-server recovered") {
            recovered = Some(line.to_string());
        }
    }
    bail!("shard-server exited before announcing a listening address")
}

/// The shard-server child process: load a snapshot (or recover a
/// durable log), build the store, and answer wire-protocol frames
/// until killed. The parent parses the announced-address line to learn
/// the kernel-chosen port; with a recoverable --wal-dir an extra
/// 'shard-server recovered ...' line precedes it.
fn cmd_shard_server(cli: &Cli) -> Result<()> {
    let shards = cli.flag_count("shards", 8, 1).map_err(anyhow::Error::msg)?;
    let listen = cli.flag_str("listen", "127.0.0.1:0");
    let checkpoint_every = cli.flag_u64("checkpoint-every", 8);
    let wal_dir = cli.flag("wal-dir").map(std::path::PathBuf::from);

    let load_snapshot = |missing: &str| -> Result<std::sync::Arc<serve::Store>> {
        let Some(snap_path) = cli.flag("snapshot") else { bail!("{missing}") };
        let snap = serve::snapshot::load(std::path::Path::new(snap_path))?;
        Ok(std::sync::Arc::new(snap.into_store(shards)))
    };
    let server = match &wal_dir {
        Some(dir) if serve::DurableLog::exists(dir) => {
            // the log alone rebuilds the store: checkpoint load, then
            // tail replay; --snapshot is not needed on this path
            let rec = serve::DurableLog::recover(dir, checkpoint_every)?;
            let r = &rec.report;
            println!(
                "shard-server recovered epoch={} sources={} checksum={:016x} \
                 checkpoint_ms={:.1} replay_ms={:.1} records={}",
                r.recovered_epoch,
                r.rows,
                r.checksum,
                r.checkpoint_load_s * 1e3,
                r.replay_s * 1e3,
                r.records_replayed
            );
            serve::ShardServer::bind_durable(rec.versioned, Some(rec.log), listen)?
        }
        Some(dir) => {
            let store = load_snapshot(&format!(
                "--wal-dir {} holds no checkpoint to recover; seed it with --snapshot FILE",
                dir.display()
            ))?;
            let versioned = std::sync::Arc::new(serve::VersionedStore::new(store));
            let log = std::sync::Arc::new(serve::DurableLog::create(
                dir,
                checkpoint_every,
                &versioned.load(),
            )?);
            versioned.attach_wal(std::sync::Arc::clone(&log));
            serve::ShardServer::bind_durable(versioned, Some(log), listen)?
        }
        None => {
            let store = load_snapshot(
                "shard-server needs --snapshot FILE (written by `infer --snapshot`, \
                 `photo --snapshot`, or the serve-bench tcp driver) or a recoverable \
                 --wal-dir",
            )?;
            serve::ShardServer::bind(store, listen)?
        }
    };
    println!("shard-server listening on {}", server.local_addr());
    use std::io::Write;
    std::io::stdout().flush().ok();
    // graceful SIGTERM: the accept loop polls the flag, flushes a
    // final fsynced checkpoint (when a WAL is attached), and reports
    // what it flushed before exiting — the parent forwards this line
    serve::net::signal::install_term_handler();
    if let Some(rep) = server.run_graceful(serve::net::signal::term_requested) {
        println!(
            "shard-server terminated epoch={} frames={} stale_refusals={} wal_synced={}",
            rep.epoch, rep.frames, rep.stale_refusals, rep.wal_synced
        );
        std::io::stdout().flush().ok();
    }
    Ok(())
}

/// Measure the recovery time objective end to end: ingest --publishes
/// epochs through a durable log, drop every in-memory structure (the
/// simulated crash), recover from disk alone, and verify the recovered
/// catalog hashes identically to the write-side mirror.
fn cmd_recover_bench(cli: &Cli) -> Result<()> {
    let count = |key, default, min| cli.flag_count(key, default, min).map_err(anyhow::Error::msg);
    let n_sources = count("sources", 5000, 1)?;
    let shards = count("shards", 8, 1)?;
    let publishes = count("publishes", 200, 1)?;
    let batch = count("ingest-batch", 32, 1)?;
    let checkpoint_every = cli.flag_u64("checkpoint-every", 32);
    let compact_threshold = cli.flag_parse("compact-threshold", 0.0f64);
    if cli.flag("compact-threshold").is_some() && compact_threshold <= 1.0 {
        bail!(
            "--compact-threshold is a max/mean shard-row skew ratio and must exceed 1.0, \
             got {compact_threshold}"
        );
    }
    let seed = cli.flag_u64("seed", 42);
    let (wal_dir, ephemeral) = match cli.flag("wal-dir") {
        Some(dir) => (std::path::PathBuf::from(dir), false),
        None => (
            std::env::temp_dir().join(format!("celeste-recover-bench-{}", std::process::id())),
            true,
        ),
    };
    if serve::DurableLog::exists(&wal_dir) {
        bail!(
            "--wal-dir {} already holds a checkpoint; point recover-bench at an empty \
             directory (it measures a fresh log's recovery)",
            wal_dir.display()
        );
    }

    // write side: durable ingestion of `publishes` drift epochs
    let snap = serve::snapshot::synthetic(n_sources, seed);
    let (width, height) = (snap.width, snap.height);
    let store = std::sync::Arc::new(snap.into_store(shards));
    println!("{}", store.summary());
    let versioned = std::sync::Arc::new(serve::VersionedStore::new(store));
    let log = std::sync::Arc::new(serve::DurableLog::create(
        &wal_dir,
        checkpoint_every,
        &versioned.load(),
    )?);
    versioned.attach_wal(std::sync::Arc::clone(&log));
    let hotspot = if compact_threshold > 0.0 { 0.8 } else { 0.0 };
    let mut drift = serve::DriftGen::new(
        &versioned.load().store.all_sources(),
        width,
        height,
        serve::DriftConfig { batch, hotspot, seed: seed ^ 0xd21f, ..Default::default() },
    );
    let mut ing = serve::Ingestor::new(std::sync::Arc::clone(&versioned));
    let mut compactor =
        (compact_threshold > 0.0).then(|| serve::Compactor::new(compact_threshold, 3));
    let (mut compactions, mut compacted_rows) = (0u64, 0u64);
    let sw = celeste::metrics::Stopwatch::start();
    for _ in 0..publishes {
        let rows = drift.next_batch();
        ing.apply(&rows);
        if let Some(c) = compactor.as_mut() {
            if c.observe(&versioned.load().store) {
                if let Some(rep) = ing.compact(compact_threshold) {
                    compactions += 1;
                    compacted_rows += rep.rows_resharded as u64;
                    println!(
                        "compaction at epoch {}: {} split(s) {} merge(s), {} row(s) \
                         resharded, skew {:.2} -> {:.2}",
                        rep.epoch,
                        rep.splits,
                        rep.merges,
                        rep.rows_resharded,
                        rep.skew_before,
                        rep.skew_after
                    );
                }
            }
        }
    }
    let ingest_s = sw.elapsed_secs();
    let final_epoch = versioned.epoch();
    let want = serve::catalog_checksum(drift.mirror());
    let ws = log.obs().snapshot();
    let appends = ws.counters.get("wal_appends").copied().unwrap_or(0);
    let bytes = ws.counters.get("wal_bytes").copied().unwrap_or(0);
    let checkpoints = ws.counters.get("wal_checkpoints").copied().unwrap_or(0);
    print!(
        "ingested {publishes} publish(es) to epoch {final_epoch} in {:.1} ms: {appends} WAL \
         append(s), {checkpoints} checkpoint(s), {:.2} MB logged",
        ingest_s * 1e3,
        bytes as f64 / (1024.0 * 1024.0)
    );
    match ws.histograms.get("wal_fsync_s") {
        Some(f) if f.n > 0 => {
            println!(", fsync p50={:.3}ms p99={:.3}ms", f.p50() * 1e3, f.p99() * 1e3)
        }
        _ => println!(),
    }
    if compactions > 0 {
        println!("compaction: {compactions} re-split(s), {compacted_rows} row(s) resharded");
    }

    // the crash: drop every in-memory structure, then recover from
    // disk alone and split the RTO into its two phases
    drop((ing, compactor, drift, versioned, log));
    let rec = serve::DurableLog::recover(&wal_dir, checkpoint_every)?;
    let r = &rec.report;
    println!(
        "recovery: epoch={} ({} source(s)) in {:.1} ms (checkpoint-load {:.1} ms from epoch \
         {} + tail-replay {:.1} ms), {} record(s) replayed, {} torn byte(s) truncated",
        r.recovered_epoch,
        r.rows,
        (r.checkpoint_load_s + r.replay_s) * 1e3,
        r.checkpoint_load_s * 1e3,
        r.checkpoint_epoch,
        r.replay_s * 1e3,
        r.records_replayed,
        r.truncated_bytes
    );
    let ok = r.recovered_epoch == final_epoch && r.checksum == want;
    println!("parity: {}", if ok { "ok" } else { "MISMATCH" });
    if let Some(path) = cli.flag("obs-dump") {
        // the write-side WAL accounting (wal_appends, wal_fsync_s)
        // merged with the recovery registry's gauges (recovered_epoch,
        // recovery_checkpoint_load_ms, recovery_replay_ms) — the same
        // v2 schema obs_check validates
        let merged = serve::obs::Snapshot::merge_all([&ws, &rec.log.obs().snapshot()]);
        serve::obs::write_dump(path, &merged, &[], &[], None, None)?;
        println!("wrote obs dump {path}");
    }
    if ephemeral {
        std::fs::remove_dir_all(&wal_dir).ok();
    }
    if !ok {
        bail!(
            "recovery diverged: epoch {} vs {final_epoch}, checksum {:016x} vs {want:016x}",
            r.recovered_epoch,
            r.checksum
        );
    }
    Ok(())
}

fn cmd_photo(cli: &Cli) -> Result<()> {
    let data = std::path::PathBuf::from(cli.flag_str("data", "data"));
    let fields = load_fields_dir(&data)?;
    if fields.is_empty() {
        bail!("no fields in {data:?}");
    }
    let use_coadd = cli.flag_bool("coadd");
    let mut found = Vec::new();
    if use_coadd {
        // coadd groups of fields with identical rects
        let mut groups: std::collections::BTreeMap<String, Vec<&celeste::imaging::FieldImages>> =
            Default::default();
        for f in &fields {
            let key = format!("{:?}", f.geom.rect);
            groups.entry(key).or_default().push(f);
        }
        for fs in groups.values() {
            let owned: Vec<celeste::imaging::FieldImages> = fs.iter().map(|f| (*f).clone()).collect();
            found.extend(run_photo(&coadd(&owned), &PhotoConfig::default()));
        }
    } else {
        for f in &fields {
            found.extend(run_photo(f, &PhotoConfig::default()));
        }
    }
    println!("photo found {} detections across {} field-exposures", found.len(), fields.len());
    if let Some(snap_path) = cli.flag("snapshot") {
        // the heuristic baseline becomes servable: detections flow
        // through ServedSource::from_entry into the same snapshot format
        // `serve-bench --snapshot` already accepts
        let (mut width, mut height) = (0.0f64, 0.0f64);
        for f in &fields {
            width = width.max(f.geom.rect.x0 + f.geom.rect.cols as f64);
            height = height.max(f.geom.rect.y0 + f.geom.rect.rows as f64);
        }
        let snap = serve::snapshot::from_photo(&found, width, height);
        serve::snapshot::save_sources(
            std::path::Path::new(snap_path),
            &snap.sources,
            snap.width,
            snap.height,
        )?;
        println!("wrote serve snapshot {snap_path} ({} detections)", snap.sources.len());
    }
    Ok(())
}

fn cmd_experiment(cli: &Cli) -> Result<()> {
    let name = cli.positional.first().map(String::as_str).unwrap_or("all");
    let quick = cli.flag_bool("quick");
    let threads = cli.flag_usize("threads", 1);
    let run_one = |n: &str| -> Result<()> {
        let v = match n {
            "fig1" => experiments::fig1::run(quick),
            "fig3" => experiments::fig3::run(quick),
            "fig4" => experiments::fig45::run_weak(quick),
            "fig5" => experiments::fig45::run_strong(quick),
            "fig6" => {
                // fig 6 is the sources/sec view of figs 4+5
                let w = experiments::fig45::run_weak(quick);
                let s = experiments::fig45::run_strong(quick);
                experiments::obj_pub(vec![("weak", w), ("strong", s)])
            }
            "table1" => experiments::table1::run(quick, threads)?,
            "ablations" => experiments::ablations::run(quick),
            "newton-vs-lbfgs" => experiments::newton_lbfgs::run(quick)?,
            other => bail!("unknown experiment {other}"),
        };
        let path = experiments::save_result(n, &v)?;
        println!("(saved {path:?})\n");
        Ok(())
    };
    if name == "all" {
        for n in ["fig1", "fig3", "fig4", "fig5", "ablations", "table1", "newton-vs-lbfgs"] {
            run_one(n)?;
        }
        Ok(())
    } else {
        run_one(name)
    }
}
