//! Deterministic PRNG + distribution samplers.
//!
//! The offline registry has no `rand` crate, and the project needs
//! reproducible synthetic skies anyway, so this is a first-class substrate:
//! xoshiro256++ (Blackman & Vigna) with splitmix64 seeding, plus the
//! samplers the generative model needs (normal, Poisson, gamma).

/// splitmix64 — used to expand a single `u64` seed into xoshiro state.
fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E3779B97F4A7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
    z ^ (z >> 31)
}

/// xoshiro256++ generator.
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
    /// cached second normal deviate from the polar method
    spare_normal: Option<f64>,
}

impl Rng {
    /// Create a generator from a 64-bit seed.
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let mut s = [0u64; 4];
        for slot in &mut s {
            *slot = splitmix64(&mut sm);
        }
        // all-zero state is invalid (cannot happen with splitmix64, but be safe)
        if s == [0, 0, 0, 0] {
            s[0] = 1;
        }
        Rng { s, spare_normal: None }
    }

    /// Derive an independent stream (for per-thread / per-task rngs).
    pub fn split(&mut self, stream: u64) -> Rng {
        Rng::new(self.next_u64() ^ stream.wrapping_mul(0x9E3779B97F4A7C15))
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let s = &mut self.s;
        let result = s[0]
            .wrapping_add(s[3])
            .rotate_left(23)
            .wrapping_add(s[0]);
        let t = s[1] << 17;
        s[2] ^= s[0];
        s[3] ^= s[1];
        s[1] ^= s[2];
        s[0] ^= s[3];
        s[2] ^= t;
        s[3] = s[3].rotate_left(45);
        result
    }

    /// Uniform in [0, 1) with 53-bit resolution.
    #[inline]
    pub fn uniform(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform in [lo, hi).
    #[inline]
    pub fn uniform_in(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.uniform()
    }

    /// Uniform integer in [0, n).
    pub fn below(&mut self, n: u64) -> u64 {
        // Lemire's nearly-divisionless method, simplified (n << 2^64).
        debug_assert!(n > 0);
        let threshold = n.wrapping_neg() % n;
        loop {
            let r = self.next_u64();
            let (hi, lo) = {
                let wide = (r as u128) * (n as u128);
                ((wide >> 64) as u64, wide as u64)
            };
            if lo >= threshold {
                return hi;
            }
        }
    }

    /// Standard normal via the Marsaglia polar method (with caching).
    pub fn normal(&mut self) -> f64 {
        if let Some(z) = self.spare_normal.take() {
            return z;
        }
        loop {
            let u = 2.0 * self.uniform() - 1.0;
            let v = 2.0 * self.uniform() - 1.0;
            let s = u * u + v * v;
            if s > 0.0 && s < 1.0 {
                let f = (-2.0 * s.ln() / s).sqrt();
                self.spare_normal = Some(v * f);
                return u * f;
            }
        }
    }

    /// Normal with given mean and standard deviation.
    #[inline]
    pub fn normal_ms(&mut self, mean: f64, sd: f64) -> f64 {
        mean + sd * self.normal()
    }

    /// Lognormal: exp(Normal(mu, sigma)).
    #[inline]
    pub fn lognormal(&mut self, mu: f64, sigma: f64) -> f64 {
        self.normal_ms(mu, sigma).exp()
    }

    /// Poisson sampler. Knuth's product method for small lambda, the PTRS
    /// transformed-rejection method (Hörmann 1993) for lambda >= 10.
    pub fn poisson(&mut self, lambda: f64) -> u64 {
        if lambda <= 0.0 {
            return 0;
        }
        if lambda < 10.0 {
            let l = (-lambda).exp();
            let mut k = 0u64;
            let mut p = 1.0;
            loop {
                p *= self.uniform();
                if p <= l {
                    return k;
                }
                k += 1;
                // numerical guard for tiny l
                if k > 1000 {
                    return k;
                }
            }
        }
        self.poisson_ptrs(lambda)
    }

    /// PTRS: transformed rejection with squeeze, valid for lambda >= 10.
    fn poisson_ptrs(&mut self, lambda: f64) -> u64 {
        let slam = lambda.sqrt();
        let loglam = lambda.ln();
        let b = 0.931 + 2.53 * slam;
        let a = -0.059 + 0.02483 * b;
        let inv_alpha = 1.1239 + 1.1328 / (b - 3.4);
        let v_r = 0.9277 - 3.6224 / (b - 2.0);
        loop {
            let u = self.uniform() - 0.5;
            let v = self.uniform();
            let us = 0.5 - u.abs();
            let k = ((2.0 * a / us + b) * u + lambda + 0.43).floor();
            if us >= 0.07 && v <= v_r {
                return k as u64;
            }
            if k < 0.0 || (us < 0.013 && v > us) {
                continue;
            }
            if v.ln() + inv_alpha.ln() - (a / (us * us) + b).ln()
                <= k * loglam - lambda - ln_gamma(k + 1.0)
            {
                return k as u64;
            }
        }
    }

    /// Gamma(shape, scale=1) via Marsaglia-Tsang; boost trick for shape < 1.
    pub fn gamma(&mut self, shape: f64) -> f64 {
        if shape < 1.0 {
            let u: f64 = self.uniform().max(1e-300);
            return self.gamma(shape + 1.0) * u.powf(1.0 / shape);
        }
        let d = shape - 1.0 / 3.0;
        let c = 1.0 / (9.0 * d).sqrt();
        loop {
            let x = self.normal();
            let v = (1.0 + c * x).powi(3);
            if v <= 0.0 {
                continue;
            }
            let u: f64 = self.uniform();
            if u < 1.0 - 0.0331 * x.powi(4)
                || u.ln() < 0.5 * x * x + d * (1.0 - v + v.ln())
            {
                return d * v;
            }
        }
    }

    /// Fisher-Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i as u64 + 1) as usize;
            xs.swap(i, j);
        }
    }
}

/// Stirling-series log-gamma (sufficient accuracy for the PTRS acceptance
/// test; |err| < 1e-9 for x >= 8, recursion lifts smaller arguments).
pub fn ln_gamma(mut x: f64) -> f64 {
    let mut acc = 0.0;
    while x < 8.0 {
        acc -= x.ln();
        x += 1.0;
    }
    let inv = 1.0 / x;
    let inv2 = inv * inv;
    let series = inv / 12.0 * (1.0 - inv2 / 30.0 * (1.0 - inv2 * 2.0 / 7.0));
    acc + 0.5 * ((2.0 * std::f64::consts::PI).ln() - x.ln())
        + x * (x.ln() - 1.0)
        + series
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn seeds_differ() {
        assert_ne!(Rng::new(1).next_u64(), Rng::new(2).next_u64());
    }

    #[test]
    fn uniform_range_and_mean() {
        let mut r = Rng::new(7);
        let n = 20_000;
        let mut sum = 0.0;
        for _ in 0..n {
            let u = r.uniform();
            assert!((0.0..1.0).contains(&u));
            sum += u;
        }
        assert!((sum / n as f64 - 0.5).abs() < 0.01);
    }

    #[test]
    fn below_unbiased() {
        let mut r = Rng::new(3);
        let mut counts = [0u32; 7];
        for _ in 0..70_000 {
            counts[r.below(7) as usize] += 1;
        }
        for c in counts {
            assert!((c as f64 - 10_000.0).abs() < 500.0, "{c}");
        }
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(11);
        let n = 100_000;
        let (mut m, mut v) = (0.0, 0.0);
        for _ in 0..n {
            let z = r.normal();
            m += z;
            v += z * z;
        }
        m /= n as f64;
        v = v / n as f64 - m * m;
        assert!(m.abs() < 0.02, "mean {m}");
        assert!((v - 1.0).abs() < 0.03, "var {v}");
    }

    #[test]
    fn poisson_small_lambda_moments() {
        let mut r = Rng::new(13);
        for &lam in &[0.1, 1.0, 4.5, 9.0] {
            let n = 50_000;
            let mut s = 0.0;
            let mut s2 = 0.0;
            for _ in 0..n {
                let k = r.poisson(lam) as f64;
                s += k;
                s2 += k * k;
            }
            let mean = s / n as f64;
            let var = s2 / n as f64 - mean * mean;
            assert!((mean - lam).abs() < 0.15 * lam.max(0.5), "lam={lam} mean={mean}");
            assert!((var - lam).abs() < 0.2 * lam.max(0.5), "lam={lam} var={var}");
        }
    }

    #[test]
    fn poisson_large_lambda_moments() {
        let mut r = Rng::new(17);
        for &lam in &[15.0, 80.0, 1000.0] {
            let n = 30_000;
            let mut s = 0.0;
            let mut s2 = 0.0;
            for _ in 0..n {
                let k = r.poisson(lam) as f64;
                s += k;
                s2 += k * k;
            }
            let mean = s / n as f64;
            let var = s2 / n as f64 - mean * mean;
            assert!((mean - lam).abs() < 0.05 * lam, "lam={lam} mean={mean}");
            assert!((var - lam).abs() < 0.1 * lam, "lam={lam} var={var}");
        }
    }

    #[test]
    fn gamma_moments() {
        let mut r = Rng::new(19);
        for &shape in &[0.5, 1.0, 3.0, 12.0] {
            let n = 60_000;
            let mut s = 0.0;
            for _ in 0..n {
                s += r.gamma(shape);
            }
            let mean = s / n as f64;
            assert!((mean - shape).abs() < 0.05 * shape.max(1.0), "k={shape} mean={mean}");
        }
    }

    #[test]
    fn ln_gamma_accuracy() {
        // ln((n-1)!) for small integers
        let facts = [0.0, 0.0, 2.0_f64.ln(), 6.0_f64.ln(), 24.0_f64.ln()];
        for (i, want) in facts.iter().enumerate() {
            let got = ln_gamma(i as f64 + 1.0);
            assert!((got - want).abs() < 1e-7, "{i}: {got} vs {want}");
        }
        // Gamma(0.5) = sqrt(pi)
        let half = ln_gamma(0.5);
        assert!((half - std::f64::consts::PI.sqrt().ln()).abs() < 1e-7);
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(23);
        let mut v: Vec<u32> = (0..100).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
        assert_ne!(v, (0..100).collect::<Vec<_>>()); // astronomically unlikely
    }

    #[test]
    fn split_streams_independent() {
        let mut base = Rng::new(5);
        let mut a = base.split(1);
        let mut b = base.split(2);
        let xa: Vec<u64> = (0..8).map(|_| a.next_u64()).collect();
        let xb: Vec<u64> = (0..8).map(|_| b.next_u64()).collect();
        assert_ne!(xa, xb);
    }
}
