//! The trust-region subproblem (Moré–Sorensen, via eigendecomposition).
//!
//! minimize  m(p) = gᵀp + ½ pᵀHp   subject to  ‖p‖ ≤ Δ
//!
//! With the dense eigendecomposition H = QΛQᵀ (cheap at dim ≈ 27) the
//! secular equation is solved exactly, including the hard case — the
//! robustness the paper's "Newton's method with updates constrained by a
//! trust region" needs on indefinite Hessians.

use super::{sym_eig, Mat};

#[derive(Clone, Debug)]
pub struct TrSolution {
    /// the step p
    pub step: Vec<f64>,
    /// predicted model reduction m(0) - m(p) ≥ 0
    pub predicted_reduction: f64,
    /// whether the step lies on the trust-region boundary
    pub on_boundary: bool,
}

fn model_reduction(g: &[f64], h: &Mat, p: &[f64]) -> f64 {
    let hp = h.matvec(p);
    -(super::dot(g, p) + 0.5 * super::dot(p, &hp))
}

/// Solve the trust-region subproblem exactly.
///
/// Fast path: when H is positive definite and the unconstrained Newton
/// step lies inside the region (the common case near convergence), a
/// single Cholesky solve suffices — ~100x cheaper than the
/// eigendecomposition, which is kept for boundary/indefinite/hard cases
/// (measured in EXPERIMENTS.md §Perf).
pub fn solve_trust_region(h: &Mat, g: &[f64], delta: f64) -> TrSolution {
    let n = g.len();
    assert_eq!((h.rows, h.cols), (n, n));
    assert!(delta > 0.0);

    if let Some(l) = super::cholesky(h) {
        let mut step = super::solve_cholesky(&l, g);
        for s in &mut step {
            *s = -*s;
        }
        if super::norm2(&step) <= delta {
            let pred = model_reduction(g, h, &step);
            return TrSolution { step, predicted_reduction: pred.max(0.0), on_boundary: false };
        }
    }

    let eig = sym_eig(h);
    let q = &eig.vectors;
    let lam = &eig.values;
    // g in eigenbasis
    let gt = q.transpose().matvec(g);

    let lam_min = lam[0];

    // ‖p(mu)‖ for shift mu (valid when lam_i + mu > 0 for all i)
    let p_norm = |mu: f64| -> f64 {
        gt.iter()
            .zip(lam)
            .map(|(gi, li)| (gi / (li + mu)).powi(2))
            .sum::<f64>()
            .sqrt()
    };
    let step_for = |mu: f64| -> Vec<f64> {
        let coef: Vec<f64> = gt.iter().zip(lam).map(|(gi, li)| -gi / (li + mu)).collect();
        q.matvec(&coef)
    };

    // Interior solution: H PD and ‖H⁻¹g‖ ≤ Δ.
    if lam_min > 0.0 {
        let p0 = p_norm(0.0);
        if p0 <= delta {
            let step = step_for(0.0);
            let pred = model_reduction(g, h, &step);
            return TrSolution { step, predicted_reduction: pred.max(0.0), on_boundary: false };
        }
    }

    // Boundary solution: find mu > max(0, -lam_min) with ‖p(mu)‖ = Δ.
    let mu_floor = (-lam_min).max(0.0);

    // Hard case: components of g along the minimal eigenspace vanish and
    // even at mu -> mu_floor the step is shorter than Δ.
    let at_floor_defined = gt
        .iter()
        .zip(lam)
        .all(|(gi, li)| (li + mu_floor).abs() > 1e-12 || gi.abs() < 1e-12);
    if at_floor_defined && mu_floor > 0.0 {
        let coef: Vec<f64> = gt
            .iter()
            .zip(lam)
            .map(|(gi, li)| {
                if (li + mu_floor).abs() <= 1e-12 { 0.0 } else { -gi / (li + mu_floor) }
            })
            .collect();
        let p_f = q.matvec(&coef);
        let nrm = super::norm2(&p_f);
        if nrm < delta {
            // move along the minimal eigenvector to the boundary
            let tau = (delta * delta - nrm * nrm).sqrt();
            let mut step = p_f;
            for r in 0..n {
                step[r] += tau * q[(r, 0)];
            }
            let pred = model_reduction(g, h, &step);
            return TrSolution { step, predicted_reduction: pred.max(0.0), on_boundary: true };
        }
    }

    // Newton iteration on the secular equation 1/Δ - 1/‖p(mu)‖ = 0,
    // guarded by bisection.
    let mut lo = mu_floor + 1e-12 * (1.0 + mu_floor);
    // bracket: grow hi until ‖p(hi)‖ < Δ
    let gnorm = super::norm2(g).max(1e-300);
    let mut hi = (gnorm / delta + lam.last().unwrap().abs()).max(lo * 2.0 + 1.0);
    while p_norm(hi) > delta {
        hi *= 2.0;
        if hi > 1e18 {
            break;
        }
    }
    let mut mu = 0.5 * (lo + hi);
    for _ in 0..100 {
        let nrm = p_norm(mu);
        let diff = 1.0 / delta - 1.0 / nrm.max(1e-300);
        if diff.abs() < 1e-12 {
            break;
        }
        if nrm > delta {
            lo = mu;
        } else {
            hi = mu;
        }
        // Newton step on phi(mu) = 1/delta - 1/‖p(mu)‖
        // d‖p‖/dmu = -(sum gi²/(li+mu)³)/‖p‖
        let dn: f64 = gt
            .iter()
            .zip(lam)
            .map(|(gi, li)| gi * gi / (li + mu).powi(3))
            .sum::<f64>()
            / nrm.max(1e-300);
        let dphi = -dn / (nrm * nrm).max(1e-300);
        let newton = mu - diff / dphi;
        mu = if newton.is_finite() && newton > lo && newton < hi {
            newton
        } else {
            0.5 * (lo + hi)
        };
    }

    let step = step_for(mu);
    let pred = model_reduction(g, h, &step);
    TrSolution { step, predicted_reduction: pred.max(0.0), on_boundary: true }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::norm2;
    use crate::prng::Rng;

    fn brute_force(h: &Mat, g: &[f64], delta: f64, rng: &mut Rng) -> f64 {
        // random search for the best model value (sanity lower bound)
        let n = g.len();
        let mut best = 0.0f64;
        for _ in 0..20_000 {
            let mut p: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
            let r = delta * rng.uniform().powf(1.0 / n as f64) / norm2(&p).max(1e-12);
            for v in &mut p {
                *v *= r;
            }
            best = best.max(model_reduction(g, h, &p));
        }
        best
    }

    #[test]
    fn interior_newton_step_when_pd_and_small() {
        // H = I, g small: p = -g, interior
        let h = Mat::eye(3);
        let g = vec![0.1, -0.2, 0.05];
        let sol = solve_trust_region(&h, &g, 10.0);
        assert!(!sol.on_boundary);
        for (p, gg) in sol.step.iter().zip(&g) {
            assert!((p + gg).abs() < 1e-10);
        }
    }

    #[test]
    fn boundary_when_gradient_large() {
        let h = Mat::eye(2);
        let g = vec![100.0, 0.0];
        let sol = solve_trust_region(&h, &g, 1.0);
        assert!(sol.on_boundary);
        assert!((norm2(&sol.step) - 1.0).abs() < 1e-6);
        assert!(sol.step[0] < 0.0); // descends
    }

    #[test]
    fn indefinite_hessian_descends() {
        let h = Mat::from_rows(&[&[1.0, 0.0], &[0.0, -2.0]]);
        let g = vec![0.5, 0.3];
        let sol = solve_trust_region(&h, &g, 1.0);
        assert!(sol.on_boundary);
        assert!((norm2(&sol.step) - 1.0).abs() < 1e-6);
        assert!(sol.predicted_reduction > 0.0);
    }

    #[test]
    fn hard_case_zero_gradient_component() {
        // g orthogonal to the minimal eigenvector; classic hard case
        let h = Mat::from_rows(&[&[-2.0, 0.0], &[0.0, 1.0]]);
        let g = vec![0.0, 0.5];
        let sol = solve_trust_region(&h, &g, 1.0);
        assert!(sol.on_boundary);
        assert!((norm2(&sol.step) - 1.0).abs() < 1e-6);
        // must exploit negative curvature along e1
        assert!(sol.step[0].abs() > 0.1);
    }

    #[test]
    fn near_optimal_vs_random_search() {
        let mut rng = Rng::new(21);
        for trial in 0..10 {
            let n = 6;
            let mut h = Mat::zeros(n, n);
            for i in 0..n {
                for j in 0..=i {
                    let x = rng.normal();
                    h[(i, j)] = x;
                    h[(j, i)] = x;
                }
            }
            let g: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
            let delta = 0.5 + rng.uniform();
            let sol = solve_trust_region(&h, &g, delta);
            assert!(norm2(&sol.step) <= delta * (1.0 + 1e-6), "trial {trial}");
            let rnd = brute_force(&h, &g, delta, &mut rng);
            assert!(
                sol.predicted_reduction >= rnd * (1.0 - 1e-2) - 1e-9,
                "trial {trial}: exact {} < random {}",
                sol.predicted_reduction,
                rnd
            );
        }
    }

    #[test]
    fn zero_gradient_pd_gives_zero_step() {
        let h = Mat::eye(4);
        let g = vec![0.0; 4];
        let sol = solve_trust_region(&h, &g, 1.0);
        assert!(norm2(&sol.step) < 1e-9);
    }
}
