//! Symmetric eigendecomposition via the cyclic Jacobi method.
//!
//! Robust and accurate for the small (≈27-dim) Hessians Celeste optimizes;
//! O(n³) per sweep with a handful of sweeps to converge.

use super::Mat;

/// Eigendecomposition A = V diag(values) Vᵀ with `values` ascending and
/// `vectors` holding eigenvectors as **columns**.
#[derive(Clone, Debug)]
pub struct Eig {
    pub values: Vec<f64>,
    pub vectors: Mat,
}

/// Cyclic Jacobi eigendecomposition of a symmetric matrix.
pub fn sym_eig(a: &Mat) -> Eig {
    assert_eq!(a.rows, a.cols);
    let n = a.rows;
    let mut a = a.clone();
    a.symmetrize();
    let mut v = Mat::eye(n);

    let max_sweeps = 64;
    for _ in 0..max_sweeps {
        // off-diagonal Frobenius norm
        let mut off = 0.0;
        for i in 0..n {
            for j in (i + 1)..n {
                off += a[(i, j)] * a[(i, j)];
            }
        }
        if off.sqrt() <= 1e-14 * (1.0 + a.max_abs()) {
            break;
        }
        for p in 0..n {
            for q in (p + 1)..n {
                let apq = a[(p, q)];
                // skip already-negligible elements (relative threshold) —
                // cuts the later sweeps' work dramatically
                let small = 1e-15 * (a[(p, p)].abs() + a[(q, q)].abs());
                if apq.abs() <= small.max(1e-300) {
                    continue;
                }
                let app = a[(p, p)];
                let aqq = a[(q, q)];
                let tau = (aqq - app) / (2.0 * apq);
                let t = if tau >= 0.0 {
                    1.0 / (tau + (1.0 + tau * tau).sqrt())
                } else {
                    -1.0 / (-tau + (1.0 + tau * tau).sqrt())
                };
                let c = 1.0 / (1.0 + t * t).sqrt();
                let s = t * c;
                // rotate rows/cols p, q of A
                for k in 0..n {
                    let akp = a[(k, p)];
                    let akq = a[(k, q)];
                    a[(k, p)] = c * akp - s * akq;
                    a[(k, q)] = s * akp + c * akq;
                }
                for k in 0..n {
                    let apk = a[(p, k)];
                    let aqk = a[(q, k)];
                    a[(p, k)] = c * apk - s * aqk;
                    a[(q, k)] = s * apk + c * aqk;
                }
                // accumulate rotations into V (columns are eigenvectors)
                for k in 0..n {
                    let vkp = v[(k, p)];
                    let vkq = v[(k, q)];
                    v[(k, p)] = c * vkp - s * vkq;
                    v[(k, q)] = s * vkp + c * vkq;
                }
            }
        }
    }

    // extract + sort ascending
    let mut idx: Vec<usize> = (0..n).collect();
    let diag: Vec<f64> = (0..n).map(|i| a[(i, i)]).collect();
    idx.sort_by(|&i, &j| diag[i].partial_cmp(&diag[j]).unwrap());
    let values: Vec<f64> = idx.iter().map(|&i| diag[i]).collect();
    let mut vectors = Mat::zeros(n, n);
    for (newc, &oldc) in idx.iter().enumerate() {
        for r in 0..n {
            vectors[(r, newc)] = v[(r, oldc)];
        }
    }
    Eig { values, vectors }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::prng::Rng;

    fn random_sym(n: usize, rng: &mut Rng) -> Mat {
        let mut a = Mat::zeros(n, n);
        for i in 0..n {
            for j in 0..=i {
                let x = rng.normal();
                a[(i, j)] = x;
                a[(j, i)] = x;
            }
        }
        a
    }

    #[test]
    fn diagonal_matrix() {
        let a = Mat::from_rows(&[&[3.0, 0.0], &[0.0, 1.0]]);
        let e = sym_eig(&a);
        assert!((e.values[0] - 1.0).abs() < 1e-12);
        assert!((e.values[1] - 3.0).abs() < 1e-12);
    }

    #[test]
    fn known_2x2() {
        // [[2,1],[1,2]] has eigenvalues 1 and 3
        let a = Mat::from_rows(&[&[2.0, 1.0], &[1.0, 2.0]]);
        let e = sym_eig(&a);
        assert!((e.values[0] - 1.0).abs() < 1e-12);
        assert!((e.values[1] - 3.0).abs() < 1e-12);
    }

    #[test]
    fn reconstruction_and_orthogonality() {
        let mut rng = Rng::new(4);
        for n in [3, 10, 27] {
            let a = random_sym(n, &mut rng);
            let e = sym_eig(&a);
            // V Vᵀ = I
            let vvt = e.vectors.matmul(&e.vectors.transpose());
            for i in 0..n {
                for j in 0..n {
                    let want = if i == j { 1.0 } else { 0.0 };
                    assert!((vvt[(i, j)] - want).abs() < 1e-10);
                }
            }
            // V diag(w) Vᵀ = A
            let mut d = Mat::zeros(n, n);
            for i in 0..n {
                d[(i, i)] = e.values[i];
            }
            let rec = e.vectors.matmul(&d).matmul(&e.vectors.transpose());
            for i in 0..n {
                for j in 0..n {
                    assert!(
                        (rec[(i, j)] - a[(i, j)]).abs() < 1e-9 * (1.0 + a.max_abs()),
                        "n={n} ({i},{j})"
                    );
                }
            }
        }
    }

    #[test]
    fn values_sorted_ascending() {
        let mut rng = Rng::new(9);
        let a = random_sym(12, &mut rng);
        let e = sym_eig(&a);
        for w in e.values.windows(2) {
            assert!(w[0] <= w[1] + 1e-12);
        }
    }

    #[test]
    fn trace_preserved() {
        let mut rng = Rng::new(6);
        let a = random_sym(9, &mut rng);
        let tr: f64 = (0..9).map(|i| a[(i, i)]).sum();
        let e = sym_eig(&a);
        let sum: f64 = e.values.iter().sum();
        assert!((tr - sum).abs() < 1e-9);
    }
}
