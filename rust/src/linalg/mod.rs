//! Dense linear algebra for the per-source Newton systems (dim ≈ 27).
//!
//! The offline registry has no `nalgebra`/`ndarray`, and the problems are
//! tiny (θ dimension 27), so a compact, well-tested in-house kit is both
//! sufficient and fast: column operations on row-major `Mat`, Cholesky,
//! cyclic-Jacobi symmetric eigendecomposition, and the Moré–Sorensen
//! trust-region subproblem built on top.

mod chol;
mod eig;
mod trust;

pub use chol::{cholesky, solve_cholesky, solve_spd};
pub use eig::{sym_eig, Eig};
pub use trust::{solve_trust_region, TrSolution};

/// Dense row-major matrix of `f64`.
#[derive(Clone, Debug, PartialEq)]
pub struct Mat {
    pub rows: usize,
    pub cols: usize,
    pub data: Vec<f64>,
}

impl Mat {
    pub fn zeros(rows: usize, cols: usize) -> Self {
        Mat { rows, cols, data: vec![0.0; rows * cols] }
    }

    pub fn eye(n: usize) -> Self {
        let mut m = Mat::zeros(n, n);
        for i in 0..n {
            m[(i, i)] = 1.0;
        }
        m
    }

    pub fn from_rows(rows: &[&[f64]]) -> Self {
        let r = rows.len();
        let c = rows.first().map_or(0, |x| x.len());
        let mut data = Vec::with_capacity(r * c);
        for row in rows {
            assert_eq!(row.len(), c, "ragged rows");
            data.extend_from_slice(row);
        }
        Mat { rows: r, cols: c, data }
    }

    /// Build from a flat row-major slice.
    pub fn from_flat(rows: usize, cols: usize, data: &[f64]) -> Self {
        assert_eq!(data.len(), rows * cols);
        Mat { rows, cols, data: data.to_vec() }
    }

    #[inline]
    pub fn row(&self, i: usize) -> &[f64] {
        &self.data[i * self.cols..(i + 1) * self.cols]
    }

    pub fn transpose(&self) -> Mat {
        let mut t = Mat::zeros(self.cols, self.rows);
        for i in 0..self.rows {
            for j in 0..self.cols {
                t[(j, i)] = self[(i, j)];
            }
        }
        t
    }

    /// y = A x
    pub fn matvec(&self, x: &[f64]) -> Vec<f64> {
        assert_eq!(x.len(), self.cols);
        let mut y = vec![0.0; self.rows];
        for i in 0..self.rows {
            let row = self.row(i);
            let mut acc = 0.0;
            for j in 0..self.cols {
                acc += row[j] * x[j];
            }
            y[i] = acc;
        }
        y
    }

    /// C = A B
    pub fn matmul(&self, b: &Mat) -> Mat {
        assert_eq!(self.cols, b.rows);
        let mut c = Mat::zeros(self.rows, b.cols);
        for i in 0..self.rows {
            for k in 0..self.cols {
                let a_ik = self[(i, k)];
                if a_ik == 0.0 {
                    continue;
                }
                let brow = b.row(k);
                let crow = &mut c.data[i * b.cols..(i + 1) * b.cols];
                for j in 0..b.cols {
                    crow[j] += a_ik * brow[j];
                }
            }
        }
        c
    }

    /// A += s * I
    pub fn add_diag(&mut self, s: f64) {
        let n = self.rows.min(self.cols);
        for i in 0..n {
            self[(i, i)] += s;
        }
    }

    /// A += B (elementwise)
    pub fn add_assign(&mut self, b: &Mat) {
        assert_eq!((self.rows, self.cols), (b.rows, b.cols));
        for (a, &bb) in self.data.iter_mut().zip(&b.data) {
            *a += bb;
        }
    }

    /// Enforce exact symmetry: A = (A + Aᵀ)/2.
    pub fn symmetrize(&mut self) {
        assert_eq!(self.rows, self.cols);
        for i in 0..self.rows {
            for j in (i + 1)..self.cols {
                let v = 0.5 * (self[(i, j)] + self[(j, i)]);
                self[(i, j)] = v;
                self[(j, i)] = v;
            }
        }
    }

    pub fn max_abs(&self) -> f64 {
        self.data.iter().fold(0.0, |m, &x| m.max(x.abs()))
    }
}

impl std::ops::Index<(usize, usize)> for Mat {
    type Output = f64;
    #[inline]
    fn index(&self, (i, j): (usize, usize)) -> &f64 {
        &self.data[i * self.cols + j]
    }
}

impl std::ops::IndexMut<(usize, usize)> for Mat {
    #[inline]
    fn index_mut(&mut self, (i, j): (usize, usize)) -> &mut f64 {
        &mut self.data[i * self.cols + j]
    }
}

// -------------------------------------------------------------------------
// Vector helpers
// -------------------------------------------------------------------------

#[inline]
pub fn dot(a: &[f64], b: &[f64]) -> f64 {
    debug_assert_eq!(a.len(), b.len());
    a.iter().zip(b).map(|(x, y)| x * y).sum()
}

#[inline]
pub fn norm2(a: &[f64]) -> f64 {
    dot(a, a).sqrt()
}

/// y += alpha * x
#[inline]
pub fn axpy(alpha: f64, x: &[f64], y: &mut [f64]) {
    debug_assert_eq!(x.len(), y.len());
    for (yi, &xi) in y.iter_mut().zip(x) {
        *yi += alpha * xi;
    }
}

/// y = alpha * x
#[inline]
pub fn scale(alpha: f64, x: &[f64]) -> Vec<f64> {
    x.iter().map(|&v| alpha * v).collect()
}

/// a - b
#[inline]
pub fn sub(a: &[f64], b: &[f64]) -> Vec<f64> {
    a.iter().zip(b).map(|(x, y)| x - y).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn matmul_identity() {
        let a = Mat::from_rows(&[&[1.0, 2.0], &[3.0, 4.0]]);
        let i = Mat::eye(2);
        assert_eq!(a.matmul(&i), a);
        assert_eq!(i.matmul(&a), a);
    }

    #[test]
    fn matvec_known() {
        let a = Mat::from_rows(&[&[1.0, 2.0], &[3.0, 4.0]]);
        assert_eq!(a.matvec(&[1.0, 1.0]), vec![3.0, 7.0]);
    }

    #[test]
    fn transpose_roundtrip() {
        let a = Mat::from_rows(&[&[1.0, 2.0, 3.0], &[4.0, 5.0, 6.0]]);
        assert_eq!(a.transpose().transpose(), a);
    }

    #[test]
    fn symmetrize_works() {
        let mut a = Mat::from_rows(&[&[1.0, 2.0], &[4.0, 3.0]]);
        a.symmetrize();
        assert_eq!(a[(0, 1)], 3.0);
        assert_eq!(a[(1, 0)], 3.0);
    }

    #[test]
    fn vector_ops() {
        assert_eq!(dot(&[1.0, 2.0], &[3.0, 4.0]), 11.0);
        assert!((norm2(&[3.0, 4.0]) - 5.0).abs() < 1e-15);
        let mut y = vec![1.0, 1.0];
        axpy(2.0, &[1.0, 2.0], &mut y);
        assert_eq!(y, vec![3.0, 5.0]);
    }
}
