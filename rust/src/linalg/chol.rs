//! Cholesky factorization and SPD solves.

use super::Mat;

/// Lower-triangular Cholesky factor of a symmetric positive-definite
/// matrix. Returns `None` if the matrix is not (numerically) SPD.
pub fn cholesky(a: &Mat) -> Option<Mat> {
    assert_eq!(a.rows, a.cols);
    let n = a.rows;
    let mut l = Mat::zeros(n, n);
    for i in 0..n {
        for j in 0..=i {
            let mut s = a[(i, j)];
            for k in 0..j {
                s -= l[(i, k)] * l[(j, k)];
            }
            if i == j {
                if s <= 0.0 || !s.is_finite() {
                    return None;
                }
                l[(i, i)] = s.sqrt();
            } else {
                l[(i, j)] = s / l[(j, j)];
            }
        }
    }
    Some(l)
}

/// Solve L Lᵀ x = b given the Cholesky factor `l`.
pub fn solve_cholesky(l: &Mat, b: &[f64]) -> Vec<f64> {
    let n = l.rows;
    assert_eq!(b.len(), n);
    // forward: L y = b
    let mut y = vec![0.0; n];
    for i in 0..n {
        let mut s = b[i];
        for k in 0..i {
            s -= l[(i, k)] * y[k];
        }
        y[i] = s / l[(i, i)];
    }
    // backward: Lᵀ x = y
    let mut x = vec![0.0; n];
    for i in (0..n).rev() {
        let mut s = y[i];
        for k in (i + 1)..n {
            s -= l[(k, i)] * x[k];
        }
        x[i] = s / l[(i, i)];
    }
    x
}

/// One-shot SPD solve; `None` if `a` is not SPD.
pub fn solve_spd(a: &Mat, b: &[f64]) -> Option<Vec<f64>> {
    cholesky(a).map(|l| solve_cholesky(&l, b))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::prng::Rng;

    fn random_spd(n: usize, rng: &mut Rng) -> Mat {
        // A = B Bᵀ + n * I is SPD
        let mut b = Mat::zeros(n, n);
        for v in &mut b.data {
            *v = rng.normal();
        }
        let mut a = b.matmul(&b.transpose());
        a.add_diag(n as f64);
        a
    }

    #[test]
    fn factor_reconstructs() {
        let mut rng = Rng::new(1);
        for n in [1, 2, 5, 27] {
            let a = random_spd(n, &mut rng);
            let l = cholesky(&a).expect("SPD");
            let rec = l.matmul(&l.transpose());
            for i in 0..n {
                for j in 0..n {
                    assert!((rec[(i, j)] - a[(i, j)]).abs() < 1e-9 * (1.0 + a.max_abs()));
                }
            }
        }
    }

    #[test]
    fn solve_residual_small() {
        let mut rng = Rng::new(2);
        for n in [2, 8, 27] {
            let a = random_spd(n, &mut rng);
            let b: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
            let x = solve_spd(&a, &b).unwrap();
            let r = crate::linalg::sub(&a.matvec(&x), &b);
            assert!(crate::linalg::norm2(&r) < 1e-8 * crate::linalg::norm2(&b).max(1.0));
        }
    }

    #[test]
    fn rejects_indefinite() {
        let a = Mat::from_rows(&[&[1.0, 2.0], &[2.0, 1.0]]); // eigenvalues 3, -1
        assert!(cholesky(&a).is_none());
    }

    #[test]
    fn rejects_nan() {
        let mut a = Mat::eye(3);
        a[(1, 1)] = f64::NAN;
        assert!(cholesky(&a).is_none());
    }
}
