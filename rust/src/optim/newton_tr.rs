//! Trust-region Newton (the paper's optimizer, §III-B).
//!
//! Classic TR framework (Nocedal & Wright alg. 4.1) with the subproblem
//! solved exactly by `linalg::solve_trust_region` (Moré–Sorensen on the
//! dense eigendecomposition — dimension is only 27).

use super::{NewtonObjective, OptimResult, StopReason};
use crate::linalg::{norm2, solve_trust_region};

#[derive(Clone, Debug)]
pub struct NewtonConfig {
    pub max_iter: usize,
    /// stop when ‖g‖ ≤ gtol
    pub gtol: f64,
    /// stop when |Δf| ≤ ftol·(1+|f|) for two consecutive accepted steps
    pub ftol: f64,
    pub delta0: f64,
    pub delta_max: f64,
    /// accept step if rho > eta
    pub eta: f64,
}

impl Default for NewtonConfig {
    fn default() -> Self {
        NewtonConfig {
            max_iter: 200,
            gtol: 1e-6,
            ftol: 1e-12,
            delta0: 1.0,
            delta_max: 100.0,
            eta: 0.1,
        }
    }
}

/// Minimize `obj` from `x0` with trust-region Newton.
pub fn newton_tr<O: NewtonObjective>(
    obj: &mut O,
    x0: &[f64],
    cfg: &NewtonConfig,
) -> OptimResult {
    let mut x = x0.to_vec();
    let mut delta = cfg.delta0;
    let mut f_evals = 0usize;
    let mut trace = Vec::new();

    let (mut f, mut g, mut h) = match obj.value_grad_hess(&x) {
        Some(v) => v,
        None => {
            return OptimResult {
                x,
                f: f64::NAN,
                grad_norm: f64::NAN,
                iterations: 0,
                f_evals: 1,
                stop: StopReason::EvalError,
                trace,
            }
        }
    };
    f_evals += 1;
    trace.push(f);
    let mut stall_count = 0usize;

    for iter in 0..cfg.max_iter {
        let gnorm = norm2(&g);
        if gnorm <= cfg.gtol {
            return OptimResult {
                x,
                f,
                grad_norm: gnorm,
                iterations: iter,
                f_evals,
                stop: StopReason::Converged,
                trace,
            };
        }

        let sol = solve_trust_region(&h, &g, delta);
        let x_new: Vec<f64> = x.iter().zip(&sol.step).map(|(a, b)| a + b).collect();

        let eval = obj.value_grad_hess(&x_new);
        f_evals += 1;
        let Some((f_new, g_new, h_new)) = eval else {
            // evaluation failure (NaN region): shrink and retry
            delta *= 0.25;
            if delta < 1e-12 {
                return OptimResult {
                    x,
                    f,
                    grad_norm: gnorm,
                    iterations: iter,
                    f_evals,
                    stop: StopReason::EvalError,
                    trace,
                };
            }
            continue;
        };

        let actual = f - f_new;
        let predicted = sol.predicted_reduction.max(1e-300);
        let rho = actual / predicted;

        // radius update
        if rho < 0.25 || !f_new.is_finite() {
            delta *= 0.25;
        } else if rho > 0.75 && sol.on_boundary {
            delta = (2.5 * delta).min(cfg.delta_max);
        }

        // step acceptance
        if rho > cfg.eta && f_new.is_finite() {
            let df = (f - f_new).abs();
            x = x_new;
            f = f_new;
            g = g_new;
            h = h_new;
            trace.push(f);
            if df <= cfg.ftol * (1.0 + f.abs()) {
                stall_count += 1;
                if stall_count >= 2 {
                    return OptimResult {
                        x,
                        f,
                        grad_norm: norm2(&g),
                        iterations: iter + 1,
                        f_evals,
                        stop: StopReason::Stalled,
                        trace,
                    };
                }
            } else {
                stall_count = 0;
            }
        }

        if delta < 1e-14 {
            return OptimResult {
                x,
                f,
                grad_norm: norm2(&g),
                iterations: iter + 1,
                f_evals,
                stop: StopReason::Stalled,
                trace,
            };
        }
    }

    OptimResult {
        x,
        f,
        grad_norm: norm2(&g),
        iterations: cfg.max_iter,
        f_evals,
        stop: StopReason::MaxIter,
        trace,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::optim::test_objectives::{Quadratic, Rosenbrock};

    #[test]
    fn quadratic_one_newton_step() {
        let mut q = Quadratic::ill_conditioned(8, 10.0);
        let want = q.minimizer();
        let res = newton_tr(&mut q, &vec![0.0; 8], &NewtonConfig::default());
        assert_eq!(res.stop, StopReason::Converged);
        assert!(res.iterations <= 3, "iters {}", res.iterations);
        for (a, b) in res.x.iter().zip(&want) {
            assert!((a - b).abs() < 1e-8);
        }
    }

    #[test]
    fn ill_conditioned_quadratic_still_fast() {
        let mut q = Quadratic::ill_conditioned(20, 1e6);
        let res = newton_tr(&mut q, &vec![0.0; 20], &NewtonConfig::default());
        assert!(res.converged());
        assert!(res.iterations <= 25, "iters {}", res.iterations);
    }

    #[test]
    fn rosenbrock_converges_within_50() {
        // the paper's claim: Newton-TR reaches tolerance within ~50 iters
        // (n-dim coupled Rosenbrock has a local minimum near x1 = -1;
        // start on the global basin — optimizer quality, not globality,
        // is what is under test)
        let mut r = Rosenbrock { n: 10, evals: 0 };
        let res = newton_tr(
            &mut r,
            &vec![0.5; 10],
            &NewtonConfig { max_iter: 100, ..Default::default() },
        );
        assert!(res.converged(), "{:?}", res.stop);
        assert!(res.iterations <= 60, "iters {}", res.iterations);
        for v in &res.x {
            assert!((v - 1.0).abs() < 1e-5);
        }
    }

    #[test]
    fn trace_monotone_nonincreasing() {
        let mut r = Rosenbrock { n: 6, evals: 0 };
        let res = newton_tr(&mut r, &vec![0.5; 6], &NewtonConfig::default());
        for w in res.trace.windows(2) {
            assert!(w[1] <= w[0] + 1e-12, "trace increased: {w:?}");
        }
    }

    #[test]
    fn starts_at_optimum() {
        let mut q = Quadratic::ill_conditioned(5, 10.0);
        let star = q.minimizer();
        let res = newton_tr(&mut q, &star, &NewtonConfig::default());
        assert_eq!(res.stop, StopReason::Converged);
        assert_eq!(res.iterations, 0);
    }

    #[test]
    fn eval_error_reported() {
        struct Bad;
        impl super::super::GradObjective for Bad {
            fn dim(&self) -> usize {
                2
            }
            fn value_grad(&mut self, _: &[f64]) -> Option<(f64, Vec<f64>)> {
                None
            }
        }
        impl super::super::NewtonObjective for Bad {
            fn value_grad_hess(&mut self, _: &[f64]) -> Option<(f64, Vec<f64>, crate::linalg::Mat)> {
                None
            }
        }
        let res = newton_tr(&mut Bad, &[0.0, 0.0], &NewtonConfig::default());
        assert_eq!(res.stop, StopReason::EvalError);
    }
}
