//! L-BFGS with strong-Wolfe line search — the baseline optimizer the
//! paper's earlier system used ([5]) and that §III-B retires: "some light
//! sources require thousands of L-BFGS iterations to converge".
//!
//! Two-loop recursion (Nocedal & Wright alg. 7.4) + line search
//! (alg. 3.5/3.6 with cubic interpolation in zoom).

use super::{GradObjective, OptimResult, StopReason};
use crate::linalg::{axpy, dot, norm2};

#[derive(Clone, Debug)]
pub struct LbfgsConfig {
    pub max_iter: usize,
    pub gtol: f64,
    pub ftol: f64,
    /// history length
    pub m: usize,
    /// Wolfe constants
    pub c1: f64,
    pub c2: f64,
    pub max_ls: usize,
}

impl Default for LbfgsConfig {
    fn default() -> Self {
        LbfgsConfig {
            max_iter: 5000,
            gtol: 1e-6,
            ftol: 1e-14,
            m: 10,
            c1: 1e-4,
            c2: 0.9,
            max_ls: 30,
        }
    }
}

struct Pair {
    s: Vec<f64>,
    y: Vec<f64>,
    rho: f64,
}

/// Strong-Wolfe line search. Returns (alpha, f, g, evals) or None.
fn line_search<O: GradObjective>(
    obj: &mut O,
    x: &[f64],
    d: &[f64],
    f0: f64,
    g0d: f64,
    alpha0: f64,
    cfg: &LbfgsConfig,
) -> Option<(f64, f64, Vec<f64>, usize)> {
    debug_assert!(g0d < 0.0);
    let phi = |obj: &mut O, alpha: f64| -> Option<(f64, Vec<f64>, f64)> {
        let mut xt = x.to_vec();
        axpy(alpha, d, &mut xt);
        let (f, g) = obj.value_grad(&xt)?;
        let gd = dot(&g, d);
        Some((f, g, gd))
    };

    let mut evals = 0usize;
    let mut alpha_prev = 0.0;
    let mut f_prev = f0;
    let mut alpha = alpha0;
    let mut result = None;

    for i in 0..cfg.max_ls {
        let Some((f, g, gd)) = phi(obj, alpha) else {
            // evaluation failed (overflow region): treat as "too far"
            alpha *= 0.3;
            if alpha < 1e-16 {
                break;
            }
            continue;
        };
        evals += 1;
        if !f.is_finite() {
            alpha *= 0.3;
            continue;
        }
        if f > f0 + cfg.c1 * alpha * g0d || (i > 0 && f >= f_prev) {
            result = zoom(obj, x, d, f0, g0d, alpha_prev, f_prev, alpha, cfg, &mut evals);
            break;
        }
        if gd.abs() <= -cfg.c2 * g0d {
            result = Some((alpha, f, g));
            break;
        }
        if gd >= 0.0 {
            result = zoom(obj, x, d, f0, g0d, alpha, f, alpha_prev, cfg, &mut evals);
            break;
        }
        alpha_prev = alpha;
        f_prev = f;
        alpha *= 2.0;
    }
    result.map(|(a, f, g)| (a, f, g, evals))
}

#[allow(clippy::too_many_arguments)]
fn zoom<O: GradObjective>(
    obj: &mut O,
    x: &[f64],
    d: &[f64],
    f0: f64,
    g0d: f64,
    mut lo: f64,
    mut f_lo: f64,
    mut hi: f64,
    cfg: &LbfgsConfig,
    evals: &mut usize,
) -> Option<(f64, f64, Vec<f64>)> {
    for _ in 0..cfg.max_ls {
        // bisection with a slight bias toward lo (robust; cubic would be
        // marginally faster but this is the *baseline* method)
        let alpha = 0.5 * (lo + hi);
        let mut xt = x.to_vec();
        axpy(alpha, d, &mut xt);
        let (f, g) = obj.value_grad(&xt)?;
        *evals += 1;
        let gd = dot(&g, d);
        if f > f0 + cfg.c1 * alpha * g0d || f >= f_lo {
            hi = alpha;
        } else {
            if gd.abs() <= -cfg.c2 * g0d {
                return Some((alpha, f, g));
            }
            if gd * (hi - lo) >= 0.0 {
                hi = lo;
            }
            lo = alpha;
            f_lo = f;
        }
        if (hi - lo).abs() < 1e-14 {
            return Some((alpha, f, g));
        }
    }
    None
}

/// Minimize `obj` from `x0` with L-BFGS.
pub fn lbfgs<O: GradObjective>(obj: &mut O, x0: &[f64], cfg: &LbfgsConfig) -> OptimResult {
    let n = x0.len();
    let mut x = x0.to_vec();
    let mut f_evals = 0usize;
    let mut trace = Vec::new();

    let (mut f, mut g) = match obj.value_grad(&x) {
        Some(v) => v,
        None => {
            return OptimResult {
                x,
                f: f64::NAN,
                grad_norm: f64::NAN,
                iterations: 0,
                f_evals: 1,
                stop: StopReason::EvalError,
                trace,
            }
        }
    };
    f_evals += 1;
    trace.push(f);

    let mut history: std::collections::VecDeque<Pair> = Default::default();

    for iter in 0..cfg.max_iter {
        let gnorm = norm2(&g);
        if gnorm <= cfg.gtol {
            return OptimResult {
                x,
                f,
                grad_norm: gnorm,
                iterations: iter,
                f_evals,
                stop: StopReason::Converged,
                trace,
            };
        }

        // two-loop recursion
        let mut q = g.clone();
        let mut alphas = Vec::with_capacity(history.len());
        for p in history.iter().rev() {
            let a = p.rho * dot(&p.s, &q);
            axpy(-a, &p.y, &mut q);
            alphas.push(a);
        }
        // initial scaling H0 = (sᵀy / yᵀy) I
        if let Some(p) = history.back() {
            let gamma = dot(&p.s, &p.y) / dot(&p.y, &p.y).max(1e-300);
            for v in &mut q {
                *v *= gamma;
            }
        }
        for (p, &a) in history.iter().zip(alphas.iter().rev()) {
            let b = p.rho * dot(&p.y, &q);
            axpy(a - b, &p.s, &mut q);
        }
        let mut d: Vec<f64> = q.iter().map(|v| -v).collect();
        let mut g0d = dot(&g, &d);
        if g0d >= 0.0 {
            // not a descent direction (bad curvature); reset to steepest
            history.clear();
            d = g.iter().map(|v| -v).collect();
            g0d = -gnorm * gnorm;
        }

        // Nocedal & Wright: on the first (steepest-descent-scaled)
        // iteration start with alpha ~ 1/||g|| so the step is O(1).
        let alpha0 = if history.is_empty() {
            (1.0 / norm2(&d).max(1e-300)).min(1.0)
        } else {
            1.0
        };
        let Some((alpha, f_new, g_new, ls_evals)) = line_search(obj, &x, &d, f, g0d, alpha0, cfg) else {
            return OptimResult {
                x,
                f,
                grad_norm: gnorm,
                iterations: iter,
                f_evals,
                stop: StopReason::LineSearchFailed,
                trace,
            };
        };
        f_evals += ls_evals;

        let mut s = d;
        for v in &mut s {
            *v *= alpha;
        }
        let y: Vec<f64> = g_new.iter().zip(&g).map(|(a, b)| a - b).collect();
        let sy = dot(&s, &y);
        if sy > 1e-10 * norm2(&s) * norm2(&y) {
            if history.len() == cfg.m {
                history.pop_front();
            }
            history.push_back(Pair { rho: 1.0 / sy, s: s.clone(), y });
        }

        let df = (f - f_new).abs();
        for (xi, si) in x.iter_mut().zip(&s) {
            *xi += si;
        }
        f = f_new;
        g = g_new;
        trace.push(f);

        if df <= cfg.ftol * (1.0 + f.abs()) {
            return OptimResult {
                x,
                f,
                grad_norm: norm2(&g),
                iterations: iter + 1,
                f_evals,
                stop: StopReason::Stalled,
                trace,
            };
        }
        let _ = n;
    }

    OptimResult {
        x,
        f,
        grad_norm: norm2(&g),
        iterations: cfg.max_iter,
        f_evals,
        stop: StopReason::MaxIter,
        trace,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::optim::test_objectives::{Quadratic, Rosenbrock};

    #[test]
    fn quadratic_converges() {
        let mut q = Quadratic::ill_conditioned(8, 100.0);
        let want = q.minimizer();
        let res = lbfgs(&mut q, &vec![0.0; 8], &LbfgsConfig::default());
        assert!(res.converged(), "{:?}", res.stop);
        for (a, b) in res.x.iter().zip(&want) {
            assert!((a - b).abs() < 1e-5);
        }
    }

    #[test]
    fn rosenbrock_converges() {
        let mut r = Rosenbrock { n: 8, evals: 0 };
        let res = lbfgs(&mut r, &vec![-1.2; 8], &LbfgsConfig::default());
        assert!(res.converged(), "{:?}", res.stop);
        for v in &res.x {
            assert!((v - 1.0).abs() < 1e-4, "{v}");
        }
    }

    #[test]
    fn needs_more_iters_than_newton_when_ill_conditioned() {
        // the paper's motivation for switching optimizers
        let cfg = LbfgsConfig { gtol: 1e-8, ..Default::default() };
        let mut q1 = Quadratic::ill_conditioned(20, 1e6);
        let lb = lbfgs(&mut q1, &vec![0.0; 20], &cfg);
        let mut q2 = Quadratic::ill_conditioned(20, 1e6);
        let nt = crate::optim::newton_tr(
            &mut q2,
            &vec![0.0; 20],
            &crate::optim::NewtonConfig { gtol: 1e-8, ..Default::default() },
        );
        assert!(lb.iterations > 4 * nt.iterations.max(1), "lbfgs {} newton {}", lb.iterations, nt.iterations);
    }

    #[test]
    fn wolfe_conditions_hold_on_accepted_step() {
        let mut q = Quadratic::ill_conditioned(4, 10.0);
        let x = vec![3.0, -2.0, 1.0, 0.5];
        let (f0, g0) = q.value_grad(&x).unwrap();
        let d: Vec<f64> = g0.iter().map(|v| -v).collect();
        let g0d = dot(&g0, &d);
        let cfg = LbfgsConfig::default();
        let (alpha, f1, g1, _) = line_search(&mut q, &x, &d, f0, g0d, 1.0, &cfg).unwrap();
        assert!(f1 <= f0 + cfg.c1 * alpha * g0d + 1e-12, "Armijo");
        assert!(dot(&g1, &d).abs() <= -cfg.c2 * g0d + 1e-12, "curvature");
    }

    #[test]
    fn trace_decreases() {
        let mut r = Rosenbrock { n: 4, evals: 0 };
        let res = lbfgs(&mut r, &vec![0.0; 4], &LbfgsConfig::default());
        assert!(res.trace.last().unwrap() < res.trace.first().unwrap());
    }
}
