//! Split-evaluation trust-region Newton (the §Perf optimization).
//!
//! The compiled autodiff Hessian costs ~60x a value+gradient evaluation
//! (441 ms vs 7 ms per execute, EXPERIMENTS.md §Perf), so this variant:
//!   * evaluates *trial* points with the cheap value+grad path only
//!     (rejected steps never pay for a Hessian), and
//!   * refreshes the Hessian lazily (Shamanskii scheme): a successful,
//!     well-predicted step reuses the current Hessian for the next one.
//!
//! Actual reductions are always differences of the *same* cheap
//! evaluator, so the acceptance test is unaffected by the small
//! cross-artifact numerical offset.

use super::{NewtonObjective, OptimResult, StopReason};
use crate::linalg::{norm2, solve_trust_region, Mat};

pub use super::newton_tr::NewtonConfig;

#[derive(Clone, Debug)]
pub struct SplitConfig {
    pub base: NewtonConfig,
    /// maximum consecutive steps reusing one Hessian
    pub hess_reuse: usize,
    /// rho above which a reused Hessian is considered still-good
    pub reuse_rho: f64,
}

impl Default for SplitConfig {
    fn default() -> Self {
        SplitConfig { base: NewtonConfig::default(), hess_reuse: 2, reuse_rho: 0.5 }
    }
}

/// Minimize with split evaluation. `obj.value_grad` must be the cheap
/// path; `obj.value_grad_hess` is only called when a fresh Hessian is
/// needed. Counts in the result: `f_evals` = cheap evals, and the number
/// of Hessian evaluations is reported via `hess_evals`.
pub fn newton_tr_split<O: NewtonObjective>(
    obj: &mut O,
    x0: &[f64],
    cfg: &SplitConfig,
) -> (OptimResult, usize) {
    let b = &cfg.base;
    let mut x = x0.to_vec();
    let mut delta = b.delta0;
    let mut f_evals = 0usize;
    let mut hess_evals = 0usize;
    let mut trace = Vec::new();

    let Some((mut f, mut g)) = obj.value_grad(&x) else {
        return (
            OptimResult {
                x,
                f: f64::NAN,
                grad_norm: f64::NAN,
                iterations: 0,
                f_evals: 1,
                stop: StopReason::EvalError,
                trace,
            },
            0,
        );
    };
    f_evals += 1;
    trace.push(f);

    let mut h: Option<Mat> = None;
    let mut steps_on_h = 0usize;
    let mut stall = 0usize;

    for iter in 0..b.max_iter {
        let gnorm = norm2(&g);
        if gnorm <= b.gtol {
            return (
                OptimResult {
                    x,
                    f,
                    grad_norm: gnorm,
                    iterations: iter,
                    f_evals,
                    stop: StopReason::Converged,
                    trace,
                },
                hess_evals,
            );
        }

        // (re)compute the Hessian when stale
        if h.is_none() {
            match obj.value_grad_hess(&x) {
                Some((_, _, hh)) => {
                    h = Some(hh);
                    hess_evals += 1;
                    steps_on_h = 0;
                }
                None => {
                    return (
                        OptimResult {
                            x,
                            f,
                            grad_norm: gnorm,
                            iterations: iter,
                            f_evals,
                            stop: StopReason::EvalError,
                            trace,
                        },
                        hess_evals,
                    );
                }
            }
        }

        let sol = solve_trust_region(h.as_ref().unwrap(), &g, delta);
        let x_new: Vec<f64> = x.iter().zip(&sol.step).map(|(a, s)| a + s).collect();
        let trial = obj.value_grad(&x_new);
        f_evals += 1;
        let Some((f_new, g_new)) = trial else {
            delta *= 0.25;
            if delta < 1e-14 {
                return (
                    OptimResult {
                        x,
                        f,
                        grad_norm: gnorm,
                        iterations: iter,
                        f_evals,
                        stop: StopReason::EvalError,
                        trace,
                    },
                    hess_evals,
                );
            }
            continue;
        };

        let predicted = sol.predicted_reduction.max(1e-300);
        let rho = (f - f_new) / predicted;

        if rho < 0.25 || !f_new.is_finite() {
            delta *= 0.25;
        } else if rho > 0.75 && sol.on_boundary {
            delta = (2.5 * delta).min(b.delta_max);
        }

        if rho > b.eta && f_new.is_finite() {
            let df = (f - f_new).abs();
            x = x_new;
            f = f_new;
            g = g_new;
            trace.push(f);
            steps_on_h += 1;
            // Shamanskii reuse: keep H while it predicts well
            if rho < cfg.reuse_rho || steps_on_h >= cfg.hess_reuse {
                h = None;
            }
            if df <= b.ftol * (1.0 + f.abs()) {
                stall += 1;
                if stall >= 2 {
                    return (
                        OptimResult {
                            x,
                            f,
                            grad_norm: norm2(&g),
                            iterations: iter + 1,
                            f_evals,
                            stop: StopReason::Stalled,
                            trace,
                        },
                        hess_evals,
                    );
                }
            } else {
                stall = 0;
            }
        } else {
            // rejected: the model was poor — refresh H next round
            h = None;
        }

        if delta < 1e-14 {
            return (
                OptimResult {
                    x,
                    f,
                    grad_norm: norm2(&g),
                    iterations: iter + 1,
                    f_evals,
                    stop: StopReason::Stalled,
                    trace,
                },
                hess_evals,
            );
        }
    }

    let gn = norm2(&g);
    (
        OptimResult {
            x,
            f,
            grad_norm: gn,
            iterations: b.max_iter,
            f_evals,
            stop: StopReason::MaxIter,
            trace,
        },
        hess_evals,
    )
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::optim::test_objectives::{Quadratic, Rosenbrock};

    #[test]
    fn quadratic_converges_with_few_hessians() {
        let mut q = Quadratic::ill_conditioned(10, 100.0);
        let (res, hess) = newton_tr_split(&mut q, &vec![0.0; 10], &SplitConfig::default());
        assert!(res.converged(), "{:?}", res.stop);
        assert!(hess <= res.iterations.max(1), "hessians {hess} iters {}", res.iterations);
        let want = q.minimizer();
        for (a, b) in res.x.iter().zip(&want) {
            assert!((a - b).abs() < 1e-6);
        }
    }

    #[test]
    fn rosenbrock_converges() {
        let mut r = Rosenbrock { n: 8, evals: 0 };
        let (res, hess) = newton_tr_split(&mut r, &vec![0.5; 8], &SplitConfig::default());
        assert!(res.converged(), "{:?}", res.stop);
        for v in &res.x {
            assert!((v - 1.0).abs() < 1e-5);
        }
        // Hessian reuse must actually reuse
        assert!(hess < res.iterations, "hess {hess} vs iters {}", res.iterations);
    }

    #[test]
    fn matches_full_newton_quality() {
        let mut r1 = Rosenbrock { n: 6, evals: 0 };
        let (split, _) = newton_tr_split(&mut r1, &vec![0.3; 6], &SplitConfig::default());
        let mut r2 = Rosenbrock { n: 6, evals: 0 };
        let full = crate::optim::newton_tr(&mut r2, &vec![0.3; 6], &NewtonConfig::default());
        assert!((split.f - full.f).abs() < 1e-8);
    }
}
