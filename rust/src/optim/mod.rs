//! Per-source numerical optimization.
//!
//! The paper's key algorithmic change (§III-B): replace L-BFGS with a
//! trust-region Newton method using exact (compiled-autodiff) dense
//! Hessians — "Newton's method consistently reaches machine tolerance
//! within 50 iterations" while "some light sources require thousands of
//! L-BFGS iterations". Both are implemented here so the claim is
//! reproducible (`celeste experiment newton-vs-lbfgs`).

pub mod lbfgs;
pub mod newton_split;
pub mod newton_tr;

pub use lbfgs::{lbfgs, LbfgsConfig};
pub use newton_split::{newton_tr_split, SplitConfig};
pub use newton_tr::{newton_tr, NewtonConfig};

use crate::linalg::Mat;

/// First-order objective: value + gradient. Implementations may fail
/// (artifact execution is fallible), surfacing as `None`.
pub trait GradObjective {
    fn dim(&self) -> usize;
    fn value_grad(&mut self, x: &[f64]) -> Option<(f64, Vec<f64>)>;
}

/// Second-order objective: adds the dense Hessian.
pub trait NewtonObjective: GradObjective {
    fn value_grad_hess(&mut self, x: &[f64]) -> Option<(f64, Vec<f64>, Mat)>;
}

/// Why an optimizer run stopped.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum StopReason {
    /// gradient norm below tolerance
    Converged,
    /// step/function change negligible
    Stalled,
    /// iteration cap
    MaxIter,
    /// objective evaluation failed
    EvalError,
    /// line search failed to make progress
    LineSearchFailed,
}

/// Result of one per-source optimization.
#[derive(Clone, Debug)]
pub struct OptimResult {
    pub x: Vec<f64>,
    pub f: f64,
    pub grad_norm: f64,
    pub iterations: usize,
    pub f_evals: usize,
    pub stop: StopReason,
    /// objective value per iteration (for convergence plots)
    pub trace: Vec<f64>,
}

impl OptimResult {
    pub fn converged(&self) -> bool {
        matches!(self.stop, StopReason::Converged | StopReason::Stalled)
    }
}

/// Test objectives shared by the optimizer unit tests and benches.
#[cfg(test)]
pub(crate) mod test_objectives {
    use super::*;

    /// Convex quadratic ½ xᵀAx − bᵀx with prescribed eigenvalues.
    pub struct Quadratic {
        pub a: Mat,
        pub b: Vec<f64>,
        pub evals: usize,
    }

    impl Quadratic {
        pub fn ill_conditioned(n: usize, cond: f64) -> Quadratic {
            let mut a = Mat::zeros(n, n);
            for i in 0..n {
                // log-spaced eigenvalues from 1 to cond
                a[(i, i)] = cond.powf(i as f64 / (n - 1).max(1) as f64);
            }
            Quadratic { a, b: vec![1.0; n], evals: 0 }
        }

        pub fn minimizer(&self) -> Vec<f64> {
            crate::linalg::solve_spd(&self.a, &self.b).unwrap()
        }
    }

    impl GradObjective for Quadratic {
        fn dim(&self) -> usize {
            self.b.len()
        }
        fn value_grad(&mut self, x: &[f64]) -> Option<(f64, Vec<f64>)> {
            self.evals += 1;
            let ax = self.a.matvec(x);
            let f = 0.5 * crate::linalg::dot(x, &ax) - crate::linalg::dot(&self.b, x);
            let g: Vec<f64> = ax.iter().zip(&self.b).map(|(a, b)| a - b).collect();
            Some((f, g))
        }
    }

    impl NewtonObjective for Quadratic {
        fn value_grad_hess(&mut self, x: &[f64]) -> Option<(f64, Vec<f64>, Mat)> {
            let (f, g) = self.value_grad(x)?;
            Some((f, g, self.a.clone()))
        }
    }

    /// The n-dimensional Rosenbrock function (nonconvex valley).
    pub struct Rosenbrock {
        pub n: usize,
        pub evals: usize,
    }

    impl GradObjective for Rosenbrock {
        fn dim(&self) -> usize {
            self.n
        }
        fn value_grad(&mut self, x: &[f64]) -> Option<(f64, Vec<f64>)> {
            self.evals += 1;
            let n = self.n;
            let mut f = 0.0;
            let mut g = vec![0.0; n];
            for i in 0..n - 1 {
                let t1 = x[i + 1] - x[i] * x[i];
                let t2 = 1.0 - x[i];
                f += 100.0 * t1 * t1 + t2 * t2;
                g[i] += -400.0 * x[i] * t1 - 2.0 * t2;
                g[i + 1] += 200.0 * t1;
            }
            Some((f, g))
        }
    }

    impl NewtonObjective for Rosenbrock {
        fn value_grad_hess(&mut self, x: &[f64]) -> Option<(f64, Vec<f64>, Mat)> {
            let (f, g) = self.value_grad(x)?;
            let n = self.n;
            let mut h = Mat::zeros(n, n);
            for i in 0..n - 1 {
                h[(i, i)] += 1200.0 * x[i] * x[i] - 400.0 * x[i + 1] + 2.0;
                h[(i, i + 1)] += -400.0 * x[i];
                h[(i + 1, i)] += -400.0 * x[i];
                h[(i + 1, i + 1)] += 200.0;
            }
            Some((f, g, h))
        }
    }
}
