//! Ablations of the paper's design choices (DESIGN.md §6 commits to
//! these): spatial task ordering, the process-level image cache, and the
//! GC emulation — each toggled independently on the same workload.

use crate::catalog::{noisy_catalog, Catalog};
use crate::cluster::workload::{build_workload, CostModel};
use crate::cluster::{simulate, ClusterConfig, GcConfig};
use crate::imaging::{Survey, SurveyConfig};
use crate::jsonlite::Value;
use crate::metrics::Component;
use crate::prng::Rng;
use crate::sky::{generate, SkyConfig};

use super::{num, obj};

pub fn run(quick: bool) -> Value {
    let n_sources = if quick { 4000 } else { 20_000 };
    let u = generate(&SkyConfig { n_sources, frac_clustered: 0.5, ..Default::default() });
    let mut rng = Rng::new(5);
    let cat = noisy_catalog(&u.sources, u.width, u.height, &mut rng, 0.5, 0.2);
    let survey = Survey::layout(SurveyConfig { n_epochs: 2, ..Default::default() });

    let cluster = |cache: f64, gc: bool| ClusterConfig {
        nodes: 8,
        procs_per_node: 8,
        threads_per_proc: 4,
        cache_bytes: cache,
        gc: if gc { Some(GcConfig::default()) } else { None },
        ..Default::default()
    };

    // --- baseline: spatial (Hilbert) order, cache on, GC on ---
    let wl = build_workload(&cat, &survey, &CostModel::default(), 120e6, 30.0, 1);
    let base = simulate(&cluster(2.4e9, true), &wl);

    // --- ablation 1: destroy spatial ordering (shuffled task ids) ---
    let mut shuffled = wl.clone();
    let mut rng2 = Rng::new(9);
    rng2.shuffle(&mut shuffled.tasks);
    let no_order = simulate(&cluster(2.4e9, true), &shuffled);

    // --- ablation 2: no image cache ---
    let no_cache = simulate(&cluster(1.0, true), &wl);

    // --- ablation 3: no GC (native Rust) ---
    let no_gc = simulate(&cluster(2.4e9, false), &wl);

    println!("== Ablations (8 nodes, same workload) ==");
    println!(
        "{:<26} {:>9} {:>10} {:>9} {:>9}",
        "variant", "src/s", "cache-hit", "fetch%", "gc%"
    );
    let mut rows = Vec::new();
    for (name, r) in [
        ("baseline (paper design)", &base),
        ("shuffled task order", &no_order),
        ("no image cache", &no_cache),
        ("no GC (native rust)", &no_gc),
    ] {
        println!(
            "{:<26} {:>9.1} {:>9.1}% {:>8.1}% {:>8.1}%",
            name,
            r.sources_per_sec,
            100.0 * r.cache_hit_rate,
            100.0 * r.breakdown.fraction(Component::GaFetch),
            100.0 * r.breakdown.fraction(Component::Gc),
        );
        rows.push(obj(vec![
            ("variant", Value::Str(name.to_string())),
            ("sources_per_sec", num(r.sources_per_sec)),
            ("cache_hit_rate", num(r.cache_hit_rate)),
            ("ga_fetch_frac", num(r.breakdown.fraction(Component::GaFetch))),
            ("gc_frac", num(r.breakdown.fraction(Component::Gc))),
        ]));
    }
    println!(
        "(spatial ordering and the image cache are the paper's two I/O\n\
         mitigations — §III-C; the no-GC row quantifies §VIII's complaint)"
    );
    obj(vec![("rows", Value::Arr(rows))])
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ablations_show_design_value() {
        let v = run(true);
        let rows = v.get("rows").unwrap().as_arr().unwrap();
        let f = |i: usize, k: &str| rows[i].get(k).unwrap().as_f64().unwrap();
        // shuffled order must hurt cache hit rate
        assert!(f(1, "cache_hit_rate") < f(0, "cache_hit_rate"));
        // removing the cache must raise fetch share
        assert!(f(2, "ga_fetch_frac") > f(0, "ga_fetch_frac"));
        // removing GC must raise throughput
        assert!(f(3, "sources_per_sec") > f(0, "sources_per_sec"));
    }
}
