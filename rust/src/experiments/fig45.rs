//! Figs 4, 5, 6: weak and strong scaling of the full distributed system,
//! 16 → 256 nodes (8 processes/node × 4 threads, as §VI-A fixes).
//!
//! Fig 4 (weak): constant sources/node; GC 15–25% throughout, image load
//! < 1%, imbalance ≤ ~6.5%, GA-fetch share growing to ~18% at 256 nodes.
//! Fig 5 (strong): 332,631 sources total; GC share falls 30% → 11% as
//! runtime shrinks while GA-fetch grows 2% → 26%.
//! Fig 6: the sources/second curves of both — perfect scaling to 64
//! nodes, then fabric-bandwidth limited.

use crate::cluster::workload::synthetic_workload;
use crate::cluster::{simulate, ClusterConfig, CostModel};
use crate::ga::FabricConfig;
use crate::jsonlite::Value;
use crate::metrics::Component;

use super::{arr, num, obj};

/// Fabric calibrated so aggregate image traffic saturates the bisection
/// beyond ~64 nodes (the knee in Fig 6) — see DESIGN.md §4.5.
fn paper_fabric() -> FabricConfig {
    FabricConfig { bisection_bw: 60e9, ..Default::default() }
}

fn cluster(nodes: usize) -> ClusterConfig {
    ClusterConfig {
        nodes,
        procs_per_node: 8,
        threads_per_proc: 4,
        fabric: paper_fabric(),
        cache_bytes: 2.4e9, // 20 fields/process
        ..Default::default()
    }
}

fn run_scaling(
    label: &str,
    node_counts: &[usize],
    tasks_for: impl Fn(usize) -> usize,
    seed: u64,
) -> Vec<Value> {
    println!("{:>6} {:>9} {:>10} {:>7} {:>7} {:>7} {:>7} {:>7}", "nodes", "tasks", "src/s", "gc%", "load%", "imbal%", "fetch%", "sched%");
    let mut rows = Vec::new();
    for &nodes in node_counts {
        let n_tasks = tasks_for(nodes);
        // ~500 sources per field (paper §III-C); tasks ordered spatially
        let n_fields = (n_tasks / 500).max(8);
        let w = synthetic_workload(n_tasks, n_fields, 3, &CostModel::default(), 120e6, seed);
        let r = simulate(&cluster(nodes), &w);
        println!(
            "{:>6} {:>9} {:>10.1} {:>6.1}% {:>6.2}% {:>6.1}% {:>6.1}% {:>6.3}%",
            nodes,
            n_tasks,
            r.sources_per_sec,
            100.0 * r.breakdown.fraction(Component::Gc),
            100.0 * r.breakdown.fraction(Component::ImageLoad),
            100.0 * r.breakdown.fraction(Component::LoadImbalance),
            100.0 * r.breakdown.fraction(Component::GaFetch),
            100.0 * r.breakdown.fraction(Component::Scheduling),
        );
        rows.push(obj(vec![
            ("nodes", num(nodes as f64)),
            ("tasks", num(n_tasks as f64)),
            ("sources_per_sec", num(r.sources_per_sec)),
            ("makespan", num(r.makespan)),
            ("gc_frac", num(r.breakdown.fraction(Component::Gc))),
            ("image_load_frac", num(r.breakdown.fraction(Component::ImageLoad))),
            ("imbalance_frac", num(r.breakdown.fraction(Component::LoadImbalance))),
            ("ga_fetch_frac", num(r.breakdown.fraction(Component::GaFetch))),
            ("sched_frac", num(r.breakdown.fraction(Component::Scheduling))),
            ("cache_hit_rate", num(r.cache_hit_rate)),
        ]));
    }
    let _ = label;
    rows
}

pub fn run_weak(quick: bool) -> Value {
    let nodes: &[usize] = if quick { &[16, 64, 256] } else { &[16, 32, 64, 128, 256] };
    println!("== Fig 4 + 6a: weak scaling (constant work per node) ==");
    // paper weak runs: ~320 sources per node-process-thread-second budget;
    // 1250 sources/node keeps runtimes in the paper's regime
    let rows = run_scaling("weak", nodes, |n| n * 1250, 11);
    println!("(paper shape: perfect sources/sec scaling to 64 nodes, then the\n GA-fetch share rises as image traffic saturates the fabric)");
    obj(vec![("rows", arr(rows))])
}

pub fn run_strong(quick: bool) -> Value {
    let nodes: &[usize] = if quick { &[16, 64, 256] } else { &[16, 32, 64, 128, 256] };
    println!("== Fig 5 + 6b: strong scaling (332,631 sources total) ==");
    let total = 332_631;
    let rows = run_scaling("strong", nodes, |_| total, 13);
    println!("(paper shape: GC share falls with runtime, 30% -> ~11%; GA fetch\n grows 2% -> ~26% at 256 nodes)");
    obj(vec![("rows", arr(rows))])
}

#[cfg(test)]
mod tests {
    use super::*;

    fn f(v: &Value, i: usize, k: &str) -> f64 {
        v.get("rows").unwrap().as_arr().unwrap()[i]
            .get(k)
            .unwrap()
            .as_f64()
            .unwrap()
    }

    #[test]
    fn weak_scaling_shape() {
        let v = run_weak(true);
        // near-perfect to 64 nodes: src/s ratio ≈ node ratio
        let r16 = f(&v, 0, "sources_per_sec");
        let r64 = f(&v, 1, "sources_per_sec");
        let r256 = f(&v, 2, "sources_per_sec");
        assert!(r64 / r16 > 3.0, "16->64 speedup {}", r64 / r16);
        // degradation past 64: efficiency drops
        let eff256 = (r256 / r16) / 16.0;
        let eff64 = (r64 / r16) / 4.0;
        assert!(eff256 < eff64, "eff64 {eff64} eff256 {eff256}");
        // fetch share grows toward the paper's ~18%
        assert!(f(&v, 2, "ga_fetch_frac") > f(&v, 0, "ga_fetch_frac"));
        // image load stays small (paper: < 1%)
        assert!(f(&v, 2, "image_load_frac") < 0.03);
    }

    #[test]
    fn strong_scaling_shape() {
        let v = run_strong(true);
        let gc16 = f(&v, 0, "gc_frac");
        let gc256 = f(&v, 2, "gc_frac");
        assert!(gc16 > gc256, "gc share falls with scale: {gc16} -> {gc256}");
        let fetch16 = f(&v, 0, "ga_fetch_frac");
        let fetch256 = f(&v, 2, "ga_fetch_frac");
        assert!(fetch256 > 2.0 * fetch16, "fetch grows: {fetch16} -> {fetch256}");
        // makespan still shrinks with nodes
        assert!(f(&v, 2, "makespan") < f(&v, 0, "makespan"));
    }
}
