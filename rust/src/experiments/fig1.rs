//! Fig 1: SDSS image boundaries — overlap structure of the survey.
//!
//! The paper's figure shows overlapping field boundaries and sources
//! imaged by multiple non-overlapping images. We reproduce the statistic
//! that matters to the system: how many fields cover each sky location,
//! and how often fields overlap.

use crate::imaging::{Survey, SurveyConfig};
use crate::jsonlite::Value;
use crate::prng::Rng;

use super::{arr, num, obj};

pub fn run(quick: bool) -> Value {
    let cfg = SurveyConfig {
        n_epochs: if quick { 2 } else { 3 },
        ..Default::default()
    };
    let survey = Survey::layout(cfg.clone());
    let overlap_pairs = survey.overlap_pairs();

    // Monte Carlo multiplicity: how many exposures cover a random point
    let mut rng = Rng::new(99);
    let probes = if quick { 2000 } else { 20_000 };
    let mut hist = vec![0usize; 16];
    for _ in 0..probes {
        let p = (
            rng.uniform_in(10.0, cfg.sky_width - 10.0),
            rng.uniform_in(10.0, cfg.sky_height - 10.0),
        );
        let k = survey.fields_containing(p, 0.0).len().min(15);
        hist[k] += 1;
    }
    let multi = hist[2..].iter().sum::<usize>() as f64 / probes as f64;

    println!("== Fig 1: survey geometry (synthetic SDSS layout) ==");
    println!("fields: {} ({} epochs)", survey.fields.len(), cfg.n_epochs);
    println!("same-epoch overlapping field pairs: {overlap_pairs}");
    println!("fraction of sky imaged >= 2 times: {multi:.3}");
    print!("coverage multiplicity histogram: ");
    for (k, h) in hist.iter().enumerate().take(8) {
        print!("{k}x:{:.1}% ", 100.0 * *h as f64 / probes as f64);
    }
    println!();
    println!(
        "(paper: \"Some images overlap substantially. Some light sources\n\
         appear in multiple images that do not overlap.\" — reproduced: the\n\
         majority of the sky is multiply imaged)"
    );

    obj(vec![
        ("fields", num(survey.fields.len() as f64)),
        ("epochs", num(cfg.n_epochs as f64)),
        ("overlap_pairs", num(overlap_pairs as f64)),
        ("frac_multiply_imaged", num(multi)),
        (
            "coverage_hist",
            arr(hist.iter().map(|&h| num(h as f64 / probes as f64))),
        ),
    ])
}

#[cfg(test)]
mod tests {
    #[test]
    fn fig1_runs_and_shows_overlap() {
        let v = super::run(true);
        assert!(v.get("overlap_pairs").unwrap().as_f64().unwrap() > 0.0);
        assert!(v.get("frac_multiply_imaged").unwrap().as_f64().unwrap() > 0.5);
    }
}
