//! Table I: average error on celestial bodies from (synthetic) Stripe 82
//! — Photo vs Celeste, both fit to a single exposure.
//!
//! Substitutions (DESIGN.md §4): the sky is synthetic, so *true*
//! parameters are known exactly and replace the paper's coadd-Photo
//! ground-truth proxy (strictly better); a 30-exposure coadd is still
//! produced and used by Photo for detection-completeness context.
//! Saturation (unflagged clipping) is injected as the paper suspects of
//! its own brightness anomaly (§VII).

use crate::catalog::noisy_catalog;
use crate::coordinator::{run_inference, InferenceConfig};
use crate::imaging::{render_field_saturating, FieldImages, Survey, SurveyConfig};
use crate::jsonlite::Value;
use crate::model::{Prior, SourceParams};
use crate::photo::{coadd, match_catalog, run_photo, PhotoConfig};
use crate::prng::Rng;
use crate::sky::{generate, SkyConfig};

use super::{num, obj};

const SATURATION: f64 = 30_000.0;

struct Errors {
    position: Vec<f64>,
    brightness: Vec<f64>,
    colors: [Vec<f64>; 4],
    profile: Vec<f64>,
    eccentricity: Vec<f64>,
    scale: Vec<f64>,
    angle: Vec<f64>,
    missed_gal: (usize, usize),  // (misclassified, total galaxies)
    missed_star: (usize, usize), // (misclassified, total stars)
}

impl Errors {
    fn new() -> Errors {
        Errors {
            position: vec![],
            brightness: vec![],
            colors: Default::default(),
            profile: vec![],
            eccentricity: vec![],
            scale: vec![],
            angle: vec![],
            missed_gal: (0, 0),
            missed_star: (0, 0),
        }
    }

    fn push(
        &mut self,
        truth: &SourceParams,
        pos: (f64, f64),
        flux_r: f64,
        colors: &[f64; 4],
        is_gal: bool,
        p_dev: f64,
        axis: f64,
        angle: f64,
        scale: f64,
    ) {
        let d = ((pos.0 - truth.pos.0).powi(2) + (pos.1 - truth.pos.1).powi(2)).sqrt();
        self.position.push(d);
        // brightness error in magnitudes
        self.brightness
            .push((2.5 * (flux_r.max(1e-3) / truth.flux_r).log10()).abs());
        for i in 0..4 {
            self.colors[i].push((colors[i] - truth.colors[i]).abs());
        }
        if truth.is_galaxy {
            self.missed_gal.1 += 1;
            if !is_gal {
                self.missed_gal.0 += 1;
            }
            // shape rows only for true galaxies measured as galaxies
            if is_gal {
                self.profile.push((p_dev - truth.shape.p_dev).abs());
                self.eccentricity.push((axis - truth.shape.axis_ratio).abs());
                self.scale.push((scale - truth.shape.scale).abs());
                let mut da = (angle - truth.shape.angle).rem_euclid(std::f64::consts::PI);
                if da > std::f64::consts::FRAC_PI_2 {
                    da = std::f64::consts::PI - da;
                }
                self.angle.push(da.to_degrees());
            }
        } else {
            self.missed_star.1 += 1;
            if is_gal {
                self.missed_star.0 += 1;
            }
        }
    }

    fn mean(v: &[f64]) -> f64 {
        if v.is_empty() {
            f64::NAN
        } else {
            v.iter().sum::<f64>() / v.len() as f64
        }
    }

    fn rows(&self) -> Vec<(String, f64)> {
        let frac = |(a, b): (usize, usize)| if b == 0 { f64::NAN } else { a as f64 / b as f64 };
        let mut out = vec![
            ("position".to_string(), Self::mean(&self.position)),
            ("missed gals".to_string(), frac(self.missed_gal)),
            ("missed stars".to_string(), frac(self.missed_star)),
            ("brightness".to_string(), Self::mean(&self.brightness)),
        ];
        for (i, name) in ["color u-g", "color g-r", "color r-i", "color i-z"].iter().enumerate() {
            out.push((name.to_string(), Self::mean(&self.colors[i])));
        }
        out.push(("profile".to_string(), Self::mean(&self.profile)));
        out.push(("eccentricity".to_string(), Self::mean(&self.eccentricity)));
        out.push(("scale".to_string(), Self::mean(&self.scale)));
        out.push(("angle".to_string(), Self::mean(&self.angle)));
        out
    }
}

pub fn run(quick: bool, threads: usize) -> anyhow::Result<Value> {
    let n_sources = if quick { 40 } else { 120 };
    let side = if quick { 256.0 } else { 384.0 };
    // a bright-ish population so Photo's detection step is not the story
    let sky = generate(&SkyConfig {
        width: side,
        height: side,
        n_sources,
        frac_clustered: 0.15,
        flux_star: (6.5, 0.8),
        flux_gal: (7.0, 0.8),
        seed: 82,
        ..Default::default()
    });
    let survey = Survey::layout(SurveyConfig {
        sky_width: side,
        sky_height: side,
        field_w: side as usize,
        field_h: side as usize,
        n_epochs: 1,
        jitter: 0.0,
        ..Default::default()
    });
    let geom = &survey.fields[0];
    let mut rng = Rng::new(820);
    // 30 exposures of the same footprint (Stripe 82), with saturation
    let exposures: Vec<FieldImages> = (0..30)
        .map(|_| render_field_saturating(&sky.sources, geom, &mut rng, SATURATION))
        .collect();
    let single = &exposures[0];

    // ---- Photo on the single exposure ----
    let photo_single = run_photo(single, &PhotoConfig::default());
    let truth_pos: Vec<(f64, f64)> = sky.sources.iter().map(|s| s.pos).collect();
    let matches = match_catalog(&photo_single, &truth_pos, 3.0);

    let mut photo_err = Errors::new();
    for &(di, ti) in &matches {
        let d = &photo_single[di];
        let t = &sky.sources[ti];
        photo_err.push(
            t, d.pos, d.flux_r, &d.colors, d.is_galaxy, d.p_dev, d.axis_ratio, d.angle, d.scale,
        );
    }

    // ---- Celeste on the same single exposure ----
    // initialized from a noisy "previous survey" catalog restricted to
    // the Photo-matched truth subset (apples-to-apples rows)
    let matched_truth: Vec<SourceParams> =
        matches.iter().map(|&(_, ti)| sky.sources[ti].clone()).collect();
    let mut rng2 = Rng::new(821);
    let catalog = noisy_catalog(&matched_truth, side, side, &mut rng2, 0.8, 0.3);
    let prior = Prior::fit(&sky.sources);
    let cfg = InferenceConfig { threads, ..Default::default() };
    let fields = vec![single.clone()];
    let (inferred, stats) = run_inference(&fields, &catalog, &prior, &cfg)?;

    let mut celeste_err = Errors::new();
    for s in &inferred {
        // catalog entry id -> nearest truth (catalog was built from
        // matched_truth in order, but Catalog::new re-sorts; match by pos)
        let (mut best, mut bi) = (f64::MAX, 0);
        for (i, t) in matched_truth.iter().enumerate() {
            let d = (t.pos.0 - s.pos.0).powi(2) + (t.pos.1 - s.pos.1).powi(2);
            if d < best {
                best = d;
                bi = i;
            }
        }
        let t = &matched_truth[bi];
        celeste_err.push(
            t,
            s.pos,
            s.est.flux_r,
            &s.est.colors,
            s.est.p_gal > 0.5,
            s.est.shape.p_dev,
            s.est.shape.axis_ratio,
            s.est.shape.angle,
            s.est.shape.scale,
        );
    }

    // ---- report ----
    println!("== Table I: average error on synthetic Stripe 82 ==");
    println!("(Photo detections matched: {} / {} sources; Celeste fit {} sources, {:.1} src/s)",
        matches.len(), n_sources, inferred.len(), stats.sources_per_sec);
    println!("{:<14} {:>8} {:>8}", "", "Photo", "Celeste");
    let prows = photo_err.rows();
    let crows = celeste_err.rows();
    let mut jrows = Vec::new();
    for ((name, pv), (_, cv)) in prows.iter().zip(&crows) {
        println!("{name:<14} {pv:>8.3} {cv:>8.3}");
        jrows.push(obj(vec![
            ("row", Value::Str(name.clone())),
            ("photo", num(*pv)),
            ("celeste", num(*cv)),
        ]));
    }
    println!(
        "(paper shape: Celeste better on position & colors by >= 30%, better\n\
         on eccentricity/angle; Photo competitive on brightness & scale)"
    );

    Ok(obj(vec![
        ("matched", num(matches.len() as f64)),
        ("celeste_sources", num(inferred.len() as f64)),
        ("rows", Value::Arr(jrows)),
    ]))
}
