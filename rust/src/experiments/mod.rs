//! Experiment harnesses: one per table/figure of the paper's evaluation
//! (§VI, §VII). Each prints the same rows/series the paper reports and
//! returns a machine-readable JSON value that the CLI can persist.
//!
//! DESIGN.md §6 maps each experiment to the subsystems it exercises.

pub mod ablations;
pub mod fig1;
pub mod fig3;
pub mod fig45;
pub mod newton_lbfgs;
pub mod table1;

use crate::jsonlite::Value;
use std::collections::BTreeMap;

/// Convenience: build a JSON object from key/value pairs (public for the
/// CLI and examples).
pub fn obj_pub(pairs: Vec<(&str, Value)>) -> Value {
    obj(pairs)
}

/// Convenience: build a JSON object from key/value pairs.
pub(crate) fn obj(pairs: Vec<(&str, Value)>) -> Value {
    let mut m = BTreeMap::new();
    for (k, v) in pairs {
        m.insert(k.to_string(), v);
    }
    Value::Obj(m)
}

pub(crate) fn num(x: f64) -> Value {
    Value::Num(x)
}

pub(crate) fn arr(xs: impl IntoIterator<Item = Value>) -> Value {
    Value::Arr(xs.into_iter().collect())
}

/// Persist an experiment result under results/.
pub fn save_result(name: &str, v: &Value) -> std::io::Result<std::path::PathBuf> {
    std::fs::create_dir_all("results")?;
    let path = std::path::PathBuf::from(format!("results/{name}.json"));
    std::fs::write(&path, crate::jsonlite::to_string(v))?;
    Ok(path)
}
