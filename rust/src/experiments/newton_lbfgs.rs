//! §III-B claim: "some light sources require thousands of L-BFGS
//! iterations ... Newton's method consistently reaches machine tolerance
//! within 50 iterations." Optimizes a corpus of synthetic sources with
//! both methods against the real compiled artifacts.

use crate::imaging::{extract_patch, Patch, Survey, SurveyConfig};
use crate::jsonlite::Value;
use crate::metrics::Stats;
use crate::model::{theta_init, GalaxyShape, Prior, SourceParams};
use crate::optim::{lbfgs, LbfgsConfig};
use crate::prng::Rng;
use crate::runtime::{ElboEngine, LikeEngine, SourceObjective};

use super::{num, obj};

fn corpus(n: usize, seed: u64) -> Vec<(SourceParams, Vec<Patch>)> {
    let survey = Survey::layout(SurveyConfig {
        sky_width: 96.0,
        sky_height: 96.0,
        field_w: 96,
        field_h: 96,
        n_epochs: 1,
        jitter: 0.0,
        ..Default::default()
    });
    let mut rng = Rng::new(seed);
    (0..n)
        .map(|i| {
            let is_galaxy = i % 3 == 0;
            let truth = SourceParams {
                pos: (48.0 + rng.uniform_in(-3.0, 3.0), 48.0 + rng.uniform_in(-3.0, 3.0)),
                is_galaxy,
                flux_r: rng.lognormal(7.0, 0.8),
                colors: [
                    rng.normal_ms(0.5, 0.2),
                    rng.normal_ms(0.4, 0.2),
                    rng.normal_ms(0.2, 0.2),
                    rng.normal_ms(0.1, 0.2),
                ],
                shape: if is_galaxy {
                    GalaxyShape {
                        p_dev: rng.uniform_in(0.2, 0.8),
                        axis_ratio: rng.uniform_in(0.3, 0.9),
                        angle: rng.uniform_in(0.0, 3.0),
                        scale: rng.uniform_in(1.0, 3.5),
                    }
                } else {
                    GalaxyShape::point_like()
                },
            };
            let fields: Vec<_> = survey
                .fields
                .iter()
                .map(|g| crate::imaging::render_field(std::slice::from_ref(&truth), g, &mut rng))
                .collect();
            let patches: Vec<Patch> = fields
                .iter()
                .filter_map(|f| extract_patch(f, truth.pos, &[]))
                .collect();
            (truth, patches)
        })
        .collect()
}

pub fn run(quick: bool) -> anyhow::Result<Value> {
    let rt = crate::runtime::load_default()?;
    let engine = ElboEngine::new(&rt, &Prior::default());
    let n = if quick { 6 } else { 24 };
    let corpus = corpus(n, 31);

    let mut newton_iters = Stats::new();
    let mut newton_evals = Stats::new();
    let mut lbfgs_iters = Stats::new();
    let mut lbfgs_evals = Stats::new();
    let mut newton_conv = 0usize;
    let mut lbfgs_conv = 0usize;

    println!("== Newton-TR vs L-BFGS on {n} sources (real artifacts) ==");
    for (truth, patches) in &corpus {
        let mut init = truth.clone();
        init.flux_r *= 1.4;
        let t0 = theta_init(&init, 0.5);

        // Newton: split evaluation (cheap Pallas trials + AD Hessians),
        // exactly the production path in `optimize_source`
        let mut on = SourceObjective::new(&engine, patches).with_engine(LikeEngine::PallasManual);
        let (rn, hn) = crate::optim::newton_tr_split(
            &mut on,
            &t0,
            &crate::optim::SplitConfig::default(),
        );
        newton_iters.push(rn.iterations as f64);
        newton_evals.push((rn.f_evals + hn) as f64);
        newton_conv += rn.converged() as usize;

        // L-BFGS on the same cheap value+grad path (fair comparison)
        let mut ol = SourceObjective::new(&engine, patches).with_engine(LikeEngine::PallasManual);
        let rl = lbfgs(&mut ol, &t0, &LbfgsConfig { max_iter: 4000, ..Default::default() });
        lbfgs_iters.push(rl.iterations as f64);
        lbfgs_evals.push(rl.f_evals as f64);
        lbfgs_conv += rl.converged() as usize;
    }

    println!(
        "newton : iters mean {:.1} max {:.0} | evals mean {:.1} max {:.0} | converged {}/{}",
        newton_iters.mean(), newton_iters.max, newton_evals.mean(), newton_evals.max, newton_conv, n
    );
    println!(
        "l-bfgs : iters mean {:.1} max {:.0} | evals mean {:.1} max {:.0} | converged {}/{}",
        lbfgs_iters.mean(), lbfgs_iters.max, lbfgs_evals.mean(), lbfgs_evals.max, lbfgs_conv, n
    );
    println!(
        "(paper: Newton <= 50 iterations; L-BFGS tail runs to thousands —\n\
         measured max Newton {:.0} vs max L-BFGS {:.0} iterations)",
        newton_iters.max, lbfgs_iters.max
    );

    Ok(obj(vec![
        ("n_sources", num(n as f64)),
        ("newton_iter_mean", num(newton_iters.mean())),
        ("newton_iter_max", num(newton_iters.max)),
        ("newton_eval_mean", num(newton_evals.mean())),
        ("newton_converged", num(newton_conv as f64)),
        ("lbfgs_iter_mean", num(lbfgs_iters.mean())),
        ("lbfgs_iter_max", num(lbfgs_iters.max)),
        ("lbfgs_eval_mean", num(lbfgs_evals.mean())),
        ("lbfgs_converged", num(lbfgs_conv as f64)),
    ]))
}
