//! Fig 3: single-node multi-threaded strong scaling — 154 light sources
//! over 1..16 threads, with the serial-GC emulation on. The paper's
//! observation: "scalability drops off beyond 4 threads; this is due to
//! serial garbage collection."

use crate::cluster::{simulate, ClusterConfig, CostModel, GcConfig};
use crate::jsonlite::Value;
use crate::metrics::Component;

use super::{arr, num, obj};

pub fn run(quick: bool) -> Value {
    let thread_counts: &[usize] = if quick { &[1, 4, 16] } else { &[1, 2, 4, 8, 16] };
    // one process with T threads (the paper's single-node study isolates
    // the threading behaviour of one Julia process)
    let n_sources = 154;

    println!("== Fig 3: single-node thread scaling, {n_sources} sources ==");
    println!("{:>7} {:>9} {:>8} {:>8} {:>8} | gc-off src/s", "threads", "src/s", "gc%", "sched%", "imbal%");

    let mut rows = Vec::new();
    for &t in thread_counts {
        let workload = crate::cluster::workload::synthetic_workload(
            n_sources,
            4,
            2,
            &CostModel::default(),
            120e6,
            7,
        );
        let mk = |gc: Option<GcConfig>| ClusterConfig {
            nodes: 1,
            procs_per_node: 1,
            threads_per_proc: t,
            gc,
            ..Default::default()
        };
        let r = simulate(&mk(Some(GcConfig::default())), &workload);
        let r_nogc = simulate(&mk(None), &workload);
        println!(
            "{:>7} {:>9.3} {:>7.1}% {:>7.2}% {:>7.1}% | {:.3}",
            t,
            r.sources_per_sec,
            100.0 * r.breakdown.fraction(Component::Gc),
            100.0 * r.breakdown.fraction(Component::Scheduling),
            100.0 * r.breakdown.fraction(Component::LoadImbalance),
            r_nogc.sources_per_sec,
        );
        rows.push(obj(vec![
            ("threads", num(t as f64)),
            ("sources_per_sec", num(r.sources_per_sec)),
            ("gc_frac", num(r.breakdown.fraction(Component::Gc))),
            ("imbalance_frac", num(r.breakdown.fraction(Component::LoadImbalance))),
            ("sources_per_sec_nogc", num(r_nogc.sources_per_sec)),
            ("makespan", num(r.makespan)),
        ]));
    }
    println!(
        "(paper shape: near-linear to 4 threads, then a GC knee — the\n\
         gc-off column is the native-Rust ablation the paper's §VIII begs for)"
    );
    obj(vec![("rows", arr(rows))])
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fig3_shape_holds() {
        let v = run(true);
        let rows = v.get("rows").unwrap().as_arr().unwrap();
        let get = |i: usize, k: &str| rows[i].get(k).unwrap().as_f64().unwrap();
        // throughput grows with threads
        assert!(get(2, "sources_per_sec") > get(0, "sources_per_sec"));
        // GC share grows with threads (the knee)
        assert!(get(2, "gc_frac") > get(1, "gc_frac"));
        // 16-thread GC run is clearly below the no-GC ablation
        assert!(get(2, "sources_per_sec_nogc") > 1.1 * get(2, "sources_per_sec"));
    }
}
