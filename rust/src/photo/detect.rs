//! Detection: SNR coadd across bands + thresholded connected components.

use crate::imaging::render::BandImage;

use super::background::SkyStats;

/// A detected pixel component.
#[derive(Clone, Debug)]
pub struct Component {
    /// flat pixel indices (row * cols + col)
    pub pixels: Vec<usize>,
    /// peak detection-image value
    pub peak: f64,
    /// index of the peak pixel
    pub peak_idx: usize,
}

/// Per-pixel detection significance: sum over bands of
/// (pixel - sky) / sigma, normalized by sqrt(n_bands).
pub fn detection_image(bands: &[BandImage], stats: &[SkyStats]) -> Vec<f64> {
    let n = bands[0].pixels.len();
    let norm = 1.0 / (bands.len() as f64).sqrt();
    let mut det = vec![0.0; n];
    for (band, st) in bands.iter().zip(stats) {
        for (d, &p) in det.iter_mut().zip(&band.pixels) {
            *d += (p as f64 - st.mean) / st.sd;
        }
    }
    for d in &mut det {
        *d *= norm;
    }
    det
}

/// 8-connected components of pixels above `threshold` sigmas, discarding
/// components smaller than `min_area`.
pub fn connected_components(
    det: &[f64],
    cols: usize,
    threshold: f64,
    min_area: usize,
) -> Vec<Component> {
    let rows = det.len() / cols;
    let mut visited = vec![false; det.len()];
    let mut out = Vec::new();
    for start in 0..det.len() {
        if visited[start] || det[start] < threshold {
            continue;
        }
        // BFS flood fill
        let mut pixels = Vec::new();
        let mut stack = vec![start];
        visited[start] = true;
        let mut peak = f64::MIN;
        let mut peak_idx = start;
        while let Some(i) = stack.pop() {
            pixels.push(i);
            if det[i] > peak {
                peak = det[i];
                peak_idx = i;
            }
            let (r, c) = (i / cols, i % cols);
            for dr in -1i64..=1 {
                for dc in -1i64..=1 {
                    if dr == 0 && dc == 0 {
                        continue;
                    }
                    let (nr, nc) = (r as i64 + dr, c as i64 + dc);
                    if nr < 0 || nc < 0 || nr >= rows as i64 || nc >= cols as i64 {
                        continue;
                    }
                    let j = nr as usize * cols + nc as usize;
                    if !visited[j] && det[j] >= threshold {
                        visited[j] = true;
                        stack.push(j);
                    }
                }
            }
        }
        if pixels.len() >= min_area {
            out.push(Component { pixels, peak, peak_idx });
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn components_split_and_merge() {
        // two blobs separated by below-threshold pixels
        let cols = 10;
        let mut det = vec![0.0; 100];
        for &i in &[11, 12, 21, 22] {
            det[i] = 10.0;
        }
        for &i in &[77, 78, 87, 88] {
            det[i] = 8.0;
        }
        let comps = connected_components(&det, cols, 5.0, 2);
        assert_eq!(comps.len(), 2);
        let sizes: Vec<usize> = comps.iter().map(|c| c.pixels.len()).collect();
        assert_eq!(sizes, vec![4, 4]);
        assert_eq!(comps[0].peak, 10.0);
    }

    #[test]
    fn min_area_filters_noise_spikes() {
        let mut det = vec![0.0; 100];
        det[55] = 100.0; // single hot pixel
        let comps = connected_components(&det, 10, 5.0, 4);
        assert!(comps.is_empty());
    }

    #[test]
    fn diagonal_connectivity() {
        let mut det = vec![0.0; 100];
        det[11] = 9.0;
        det[22] = 9.0; // diagonal neighbor
        det[33] = 9.0;
        let comps = connected_components(&det, 10, 5.0, 3);
        assert_eq!(comps.len(), 1);
        assert_eq!(comps[0].pixels.len(), 3);
    }
}
