//! Measurement: centroids, aperture photometry, moments, classification.

use crate::imaging::FieldImages;
use crate::model::layout as L;

use super::background::SkyStats;
use super::detect::Component;
use super::PhotoConfig;

/// One measured source — the heuristic pipeline's catalog row.
#[derive(Clone, Debug)]
pub struct PhotoSource {
    /// global position
    pub pos: (f64, f64),
    /// per-band aperture fluxes (gain-corrected, background-subtracted)
    pub fluxes: [f64; L::N_BANDS],
    /// reference-band flux
    pub flux_r: f64,
    /// colors (log ratios of adjacent bands; clamped for non-detections)
    pub colors: [f64; L::N_COLORS],
    pub is_galaxy: bool,
    /// deV-ness proxy from the concentration index, in [0, 1]
    pub p_dev: f64,
    pub axis_ratio: f64,
    pub angle: f64,
    /// effective radius estimate, px (PSF-deconvolved, 0 for stars)
    pub scale: f64,
    /// detection significance
    pub significance: f64,
}

/// Measure one detected component. Returns None for degenerate cases.
pub fn measure(
    field: &FieldImages,
    stats: &[SkyStats],
    det: &[f64],
    comp: &Component,
    cfg: &PhotoConfig,
) -> Option<PhotoSource> {
    let rect = field.geom.rect;
    let cols = rect.cols;

    // --- flux-weighted centroid on the detection image ---
    let (mut cx, mut cy, mut wsum) = (0.0, 0.0, 0.0);
    for &i in &comp.pixels {
        let w = det[i].max(0.0);
        cx += w * (i % cols) as f64;
        cy += w * (i / cols) as f64;
        wsum += w;
    }
    if wsum <= 0.0 {
        return None;
    }
    cx /= wsum;
    cy /= wsum;

    // --- second central moments over an inflated window ---
    // (component pixels alone truncate the wings at the detection
    // threshold, biasing sizes low; measure on the full detection image
    // in a window around the centroid instead)
    // adaptive scheme: Gaussian taper (suppresses the noise pedestal far
    // from the object) plus a 1-sigma SNR floor
    let ext = (comp.pixels.len() as f64 / std::f64::consts::PI).sqrt();
    let sigma_w = (1.2 * ext).max(2.5);
    let r_win = (3.0 * sigma_w).min(24.0);
    let (mut mxx, mut mxy, mut myy, mut msum) = (0.0, 0.0, 0.0, 0.0);
    let wr0 = (cy - r_win).floor().max(0.0) as usize;
    let wr1 = ((cy + r_win).ceil() as usize).min(rect.rows - 1);
    let wc0 = (cx - r_win).floor().max(0.0) as usize;
    let wc1 = ((cx + r_win).ceil() as usize).min(rect.cols - 1);
    for r in wr0..=wr1 {
        for c in wc0..=wc1 {
            let snr = det[r * cols + c];
            if snr < 1.0 {
                continue;
            }
            let dx = c as f64 - cx;
            let dy = r as f64 - cy;
            let w = snr * (-(dx * dx + dy * dy) / (2.0 * sigma_w * sigma_w)).exp();
            mxx += w * dx * dx;
            mxy += w * dx * dy;
            myy += w * dy * dy;
            msum += w;
        }
    }
    if msum <= 0.0 {
        return None;
    }
    mxx /= msum;
    mxy /= msum;
    myy /= msum;
    // eigen-decomposition of the 2x2 moment matrix
    let tr = mxx + myy;
    let disc = (((mxx - myy) / 2.0).powi(2) + mxy * mxy).sqrt();
    // deconvolve the Gaussian taper: 1/var = 1/var_meas - 1/sigma_w^2
    let untaper = |l: f64| {
        let l = l.max(1e-6);
        if l >= 0.9 * sigma_w * sigma_w {
            9.0 * l // window-dominated; just inflate
        } else {
            1.0 / (1.0 / l - 1.0 / (sigma_w * sigma_w))
        }
    };
    let l1 = untaper(tr / 2.0 + disc);
    let l2 = untaper(tr / 2.0 - disc);
    let angle = 0.5 * (2.0 * mxy).atan2(mxx - myy);
    let axis_ratio = (l2 / l1).sqrt().clamp(0.05, 1.0);

    // --- PSF size for star/galaxy separation ---
    // mean PSF second moment in the reference band
    let psf = &field.geom.psf[L::REF_BAND];
    let psf_var: f64 = psf.iter().map(|c| c[0] * 0.5 * (c[3] + c[5])).sum();
    let obj_var = 0.5 * (l1 + l2);
    let is_galaxy = obj_var > psf_var * (1.0 + cfg.size_margin);
    // deconvolved size
    let scale = if is_galaxy { (obj_var - psf_var).max(0.01).sqrt() } else { 0.0 };

    // --- aperture photometry per band ---
    let r_ap = (cfg.aperture_k * obj_var.sqrt()).max(cfg.min_aperture);
    let r_half = r_ap / 2.0;
    let mut fluxes = [0.0; L::N_BANDS];
    let mut inner = [0.0; L::N_BANDS];
    let r0 = (cy - r_ap).floor().max(0.0) as usize;
    let r1 = ((cy + r_ap).ceil() as usize).min(rect.rows - 1);
    let c0 = (cx - r_ap).floor().max(0.0) as usize;
    let c1 = ((cx + r_ap).ceil() as usize).min(rect.cols - 1);
    for (b, band) in field.bands.iter().enumerate() {
        let sky = stats[b].mean;
        let mut total = 0.0;
        let mut small = 0.0;
        for r in r0..=r1 {
            for c in c0..=c1 {
                let dx = c as f64 - cx;
                let dy = r as f64 - cy;
                let d2 = dx * dx + dy * dy;
                if d2 <= r_ap * r_ap {
                    let v = band.pixels[r * cols + c] as f64 - sky;
                    total += v;
                    if d2 <= r_half * r_half {
                        small += v;
                    }
                }
            }
        }
        fluxes[b] = (total / field.geom.gain[b]).max(1e-3);
        inner[b] = small.max(0.0);
    }

    // --- concentration -> profile proxy ---
    // deV profiles are more centrally concentrated than exponentials
    let conc = if fluxes[L::REF_BAND] > 0.0 {
        (inner[L::REF_BAND] / (fluxes[L::REF_BAND] * field.geom.gain[L::REF_BAND]))
            .clamp(0.0, 1.0)
    } else {
        0.5
    };
    // map concentration ~[0.55, 0.9] to p_dev [0, 1]
    let p_dev = ((conc - 0.55) / 0.35).clamp(0.0, 1.0);

    let mut colors = [0.0; L::N_COLORS];
    for i in 0..L::N_COLORS {
        colors[i] = (fluxes[i + 1] / fluxes[i]).ln().clamp(-3.0, 3.0);
    }

    Some(PhotoSource {
        pos: (rect.x0 + cx + 0.5, rect.y0 + cy + 0.5),
        fluxes,
        flux_r: fluxes[L::REF_BAND],
        colors,
        is_galaxy,
        p_dev,
        axis_ratio,
        angle,
        scale,
        significance: comp.peak,
    })
}
