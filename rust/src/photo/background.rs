//! Sigma-clipped sky background estimation.

/// Robust per-band sky statistics.
#[derive(Clone, Copy, Debug)]
pub struct SkyStats {
    pub mean: f64,
    pub sd: f64,
}

/// Iteratively sigma-clipped mean/sd (3 rounds at 3σ) — standard sky
/// estimation in the presence of sources.
pub fn sigma_clipped_stats(pixels: &[f32]) -> SkyStats {
    let mut mean = 0.0f64;
    let mut sd = f64::INFINITY;
    let mut lo = f64::NEG_INFINITY;
    let mut hi = f64::INFINITY;
    for _round in 0..4 {
        let mut n = 0u64;
        let mut s = 0.0f64;
        let mut s2 = 0.0f64;
        for &p in pixels {
            let p = p as f64;
            if p >= lo && p <= hi {
                n += 1;
                s += p;
                s2 += p * p;
            }
        }
        if n < 8 {
            break;
        }
        mean = s / n as f64;
        sd = (s2 / n as f64 - mean * mean).max(0.0).sqrt();
        lo = mean - 3.0 * sd;
        hi = mean + 3.0 * sd;
    }
    SkyStats { mean, sd: sd.max(1e-6) }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::prng::Rng;

    #[test]
    fn flat_poisson_sky() {
        let mut rng = Rng::new(1);
        let pixels: Vec<f32> = (0..65536).map(|_| rng.poisson(80.0) as f32).collect();
        let st = sigma_clipped_stats(&pixels);
        assert!((st.mean - 80.0).abs() < 0.5, "mean {}", st.mean);
        assert!((st.sd - 80.0f64.sqrt()).abs() < 0.5, "sd {}", st.sd);
    }

    #[test]
    fn robust_to_bright_contamination() {
        let mut rng = Rng::new(2);
        let mut pixels: Vec<f32> = (0..65536).map(|_| rng.poisson(60.0) as f32).collect();
        // 2% of pixels contaminated by a bright source
        for i in 0..1300 {
            pixels[i * 50] += 5000.0;
        }
        let st = sigma_clipped_stats(&pixels);
        assert!((st.mean - 60.0).abs() < 1.5, "mean {}", st.mean);
        assert!(st.sd < 12.0, "sd {}", st.sd);
    }

    #[test]
    fn tiny_input_does_not_panic() {
        let st = sigma_clipped_stats(&[1.0, 2.0, 3.0]);
        assert!(st.mean.is_finite());
        assert!(st.sd > 0.0);
    }
}
