//! "Photo" — the heuristic catalog pipeline (Lupton et al. [3]) that the
//! paper compares against (§II, §VII). Reimplemented honestly as a
//! classical detection/measurement stack: sigma-clipped background,
//! SNR-coadd thresholding, connected components, flux-weighted centroids,
//! aperture photometry, second-moment shapes, and a size-vs-PSF
//! star/galaxy separator.
//!
//! Deliberately carries the heuristic class's documented weaknesses: no
//! statistical pooling across exposures (single-frame fits or plain
//! coadds), no deblending of close pairs, and no uncertainty estimates —
//! exactly the gaps §II attributes to this family of pipelines.

mod background;
mod detect;
mod measure;

pub use background::{sigma_clipped_stats, SkyStats};
pub use detect::{connected_components, detection_image, Component};
pub use measure::{measure, PhotoSource};

use crate::imaging::FieldImages;

/// Pipeline tuning parameters.
#[derive(Clone, Debug)]
pub struct PhotoConfig {
    /// detection threshold in coadded-SNR sigmas
    pub threshold: f64,
    /// minimum component area, pixels
    pub min_area: usize,
    /// aperture radius in units of the object's rms size
    pub aperture_k: f64,
    /// minimum aperture radius, pixels
    pub min_aperture: f64,
    /// star/galaxy separation: galaxy if rms² > psf_rms² * (1 + margin)
    pub size_margin: f64,
}

impl Default for PhotoConfig {
    fn default() -> Self {
        PhotoConfig {
            threshold: 5.0,
            min_area: 4,
            aperture_k: 3.0,
            min_aperture: 4.0,
            size_margin: 0.35,
        }
    }
}

/// Run the full pipeline on one field exposure.
pub fn run_photo(field: &FieldImages, cfg: &PhotoConfig) -> Vec<PhotoSource> {
    let stats: Vec<SkyStats> = field
        .bands
        .iter()
        .map(|b| sigma_clipped_stats(&b.pixels))
        .collect();
    let det = detection_image(&field.bands, &stats);
    let comps = connected_components(
        &det,
        field.geom.rect.cols,
        cfg.threshold,
        cfg.min_area,
    );
    comps
        .into_iter()
        .filter_map(|c| measure(field, &stats, &det, &c, cfg))
        .collect()
}

/// Pixel-average coadd of repeated exposures of the same footprint (the
/// paper's stand-in ground truth runs Photo on a 30+-exposure coadd).
/// All fields must share the same rect; PSF metadata is taken from the
/// first exposure (a known approximation of real coadds).
pub fn coadd(fields: &[FieldImages]) -> FieldImages {
    assert!(!fields.is_empty());
    let first = &fields[0];
    for f in fields {
        assert_eq!(f.geom.rect, first.geom.rect, "coadd requires aligned fields");
    }
    let mut out = first.clone();
    for (b, band) in out.bands.iter_mut().enumerate() {
        let n = fields.len() as f32;
        let mut acc: Vec<f32> = vec![0.0; band.pixels.len()];
        for f in fields {
            for (a, &p) in acc.iter_mut().zip(&f.bands[b].pixels) {
                *a += p;
            }
        }
        for (dst, a) in band.pixels.iter_mut().zip(&acc) {
            *dst = a / n;
        }
        let _ = band;
    }
    out
}

/// Match detections to reference positions within `radius` px; returns
/// (det_index, ref_index) pairs, greedy nearest-first.
pub fn match_catalog(
    detections: &[PhotoSource],
    refs: &[(f64, f64)],
    radius: f64,
) -> Vec<(usize, usize)> {
    let mut pairs: Vec<(f64, usize, usize)> = Vec::new();
    for (i, d) in detections.iter().enumerate() {
        for (j, r) in refs.iter().enumerate() {
            let dist = ((d.pos.0 - r.0).powi(2) + (d.pos.1 - r.1).powi(2)).sqrt();
            if dist <= radius {
                pairs.push((dist, i, j));
            }
        }
    }
    pairs.sort_by(|a, b| a.0.partial_cmp(&b.0).unwrap());
    let mut used_d = vec![false; detections.len()];
    let mut used_r = vec![false; refs.len()];
    let mut out = Vec::new();
    for (_, i, j) in pairs {
        if !used_d[i] && !used_r[j] {
            used_d[i] = true;
            used_r[j] = true;
            out.push((i, j));
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::imaging::render::render_field;
    use crate::imaging::survey::{Survey, SurveyConfig};
    use crate::model::{GalaxyShape, SourceParams};
    use crate::prng::Rng;

    fn field_with(sources: &[SourceParams], seed: u64) -> FieldImages {
        let survey = Survey::layout(SurveyConfig {
            sky_width: 256.0,
            sky_height: 256.0,
            field_w: 256,
            field_h: 256,
            n_epochs: 1,
            jitter: 0.0,
            ..Default::default()
        });
        let mut rng = Rng::new(seed);
        render_field(sources, &survey.fields[0], &mut rng)
    }

    fn star(x: f64, y: f64, flux: f64) -> SourceParams {
        SourceParams {
            pos: (x, y),
            is_galaxy: false,
            flux_r: flux,
            colors: [0.3, 0.2, 0.1, 0.1],
            shape: GalaxyShape::point_like(),
        }
    }

    fn galaxy(x: f64, y: f64, flux: f64, scale: f64) -> SourceParams {
        SourceParams {
            pos: (x, y),
            is_galaxy: true,
            flux_r: flux,
            colors: [0.5, 0.3, 0.2, 0.1],
            shape: GalaxyShape { p_dev: 0.3, axis_ratio: 0.5, angle: 0.7, scale },
        }
    }

    #[test]
    fn detects_bright_star_with_accurate_centroid() {
        let s = star(130.3, 120.6, 3000.0);
        let f = field_with(std::slice::from_ref(&s), 1);
        let found = run_photo(&f, &PhotoConfig::default());
        assert_eq!(found.len(), 1, "one detection, got {}", found.len());
        let d = &found[0];
        let err = ((d.pos.0 - 130.3).powi(2) + (d.pos.1 - 120.6).powi(2)).sqrt();
        assert!(err < 0.35, "centroid error {err}");
        assert!(!d.is_galaxy, "star misclassified");
        assert!((d.flux_r - 3000.0).abs() / 3000.0 < 0.15, "flux {}", d.flux_r);
    }

    #[test]
    fn classifies_extended_galaxy() {
        let g = galaxy(128.0, 128.0, 8000.0, 2.8);
        let f = field_with(std::slice::from_ref(&g), 2);
        let found = run_photo(&f, &PhotoConfig::default());
        assert_eq!(found.len(), 1);
        assert!(found[0].is_galaxy, "galaxy misclassified as star");
        // shape measurements roughly sane
        assert!(found[0].axis_ratio > 0.2 && found[0].axis_ratio < 0.9);
    }

    #[test]
    fn faint_source_below_threshold_missed() {
        let s = star(128.0, 128.0, 30.0); // lost in sky noise
        let f = field_with(std::slice::from_ref(&s), 3);
        let found = run_photo(&f, &PhotoConfig::default());
        assert!(found.is_empty(), "found {}", found.len());
    }

    #[test]
    fn multiple_separated_sources() {
        let srcs = vec![star(60.0, 60.0, 2500.0), star(190.0, 70.0, 3000.0), galaxy(120.0, 190.0, 9000.0, 2.5)];
        let f = field_with(&srcs, 4);
        let found = run_photo(&f, &PhotoConfig::default());
        assert_eq!(found.len(), 3, "found {}", found.len());
        let refs: Vec<(f64, f64)> = srcs.iter().map(|s| s.pos).collect();
        let m = match_catalog(&found, &refs, 3.0);
        assert_eq!(m.len(), 3);
    }

    #[test]
    fn close_pair_blends_into_one_detection() {
        // the documented heuristic weakness: no deblending
        let srcs = vec![star(128.0, 128.0, 3000.0), star(130.5, 128.5, 2500.0)];
        let f = field_with(&srcs, 5);
        let found = run_photo(&f, &PhotoConfig::default());
        assert_eq!(found.len(), 1, "close pair should blend: {}", found.len());
    }

    #[test]
    fn coadd_reduces_noise_and_detects_fainter() {
        let s = star(128.0, 128.0, 170.0);
        let survey = Survey::layout(SurveyConfig {
            sky_width: 256.0,
            sky_height: 256.0,
            field_w: 256,
            field_h: 256,
            n_epochs: 1,
            jitter: 0.0,
            ..Default::default()
        });
        let mut rng = Rng::new(6);
        let exposures: Vec<FieldImages> = (0..30)
            .map(|_| render_field(std::slice::from_ref(&s), &survey.fields[0], &mut rng))
            .collect();
        let single = run_photo(&exposures[0], &PhotoConfig::default());
        let stacked = run_photo(&coadd(&exposures), &PhotoConfig::default());
        assert_eq!(stacked.len(), 1, "coadd should detect the faint star");
        assert!(single.len() <= stacked.len());
    }

    #[test]
    fn colors_recovered_for_bright_star() {
        let s = star(128.0, 128.0, 20_000.0);
        let f = field_with(std::slice::from_ref(&s), 7);
        let found = run_photo(&f, &PhotoConfig::default());
        assert_eq!(found.len(), 1);
        for (got, want) in found[0].colors.iter().zip(&s.colors) {
            assert!((got - want).abs() < 0.12, "color {got} vs {want}");
        }
    }
}
