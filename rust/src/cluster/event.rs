//! Discrete-event queue keyed by simulated time.

use std::cmp::Ordering;
use std::collections::BinaryHeap;

/// An event: a (process, thread) becomes ready at `time`.
#[derive(Clone, Copy, Debug)]
pub struct Event {
    pub time: f64,
    pub proc: usize,
    pub thread: usize,
    seq: u64,
}

impl PartialEq for Event {
    fn eq(&self, other: &Self) -> bool {
        self.time == other.time && self.seq == other.seq
    }
}
impl Eq for Event {}

impl Ord for Event {
    fn cmp(&self, other: &Self) -> Ordering {
        // min-heap: reverse ordering on (time, seq); ties broken by seq
        // for determinism
        other
            .time
            .partial_cmp(&self.time)
            .unwrap_or(Ordering::Equal)
            .then(other.seq.cmp(&self.seq))
    }
}
impl PartialOrd for Event {
    fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}

/// Deterministic min-heap event queue.
#[derive(Debug, Default)]
pub struct EventQueue {
    heap: BinaryHeap<Event>,
    seq: u64,
}

impl EventQueue {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn push(&mut self, time: f64, proc: usize, thread: usize) {
        debug_assert!(time.is_finite(), "non-finite event time");
        self.seq += 1;
        self.heap.push(Event { time, proc, thread, seq: self.seq });
    }

    pub fn pop(&mut self) -> Option<Event> {
        self.heap.pop()
    }

    pub fn is_empty(&self) -> bool {
        self.heap.is_empty()
    }

    pub fn len(&self) -> usize {
        self.heap.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pops_in_time_order() {
        let mut q = EventQueue::new();
        q.push(3.0, 0, 0);
        q.push(1.0, 1, 1);
        q.push(2.0, 2, 2);
        assert_eq!(q.pop().unwrap().proc, 1);
        assert_eq!(q.pop().unwrap().proc, 2);
        assert_eq!(q.pop().unwrap().proc, 0);
        assert!(q.pop().is_none());
    }

    #[test]
    fn ties_broken_by_insertion_order() {
        let mut q = EventQueue::new();
        q.push(1.0, 7, 0);
        q.push(1.0, 8, 0);
        q.push(1.0, 9, 0);
        assert_eq!(q.pop().unwrap().proc, 7);
        assert_eq!(q.pop().unwrap().proc, 8);
        assert_eq!(q.pop().unwrap().proc, 9);
    }
}
