//! Simulated multi-node runtime — the testbed substitute for Cori
//! (DESIGN.md §4.2).
//!
//! Executes the paper's three-phase algorithm (§III-D) over a
//! discrete-event model of nodes × processes × threads, a fabric-modeled
//! global-array store, the Dtree scheduler, and an (optional) emulation
//! of Julia's serial stop-the-world garbage collector (§VIII-A). Task
//! *costs* come either from a calibrated distribution or from measured
//! real optimizations; everything else — scheduling, caching, fetches,
//! GC barriers — is executed, not approximated.

pub mod event;
pub mod gc;
pub mod sim;
pub mod workload;

pub use gc::GcConfig;
pub use sim::{simulate, ClusterConfig, RunReport};
pub use workload::{CostModel, Task, Workload};
