//! Workloads for the cluster simulation: one task per candidate light
//! source (the paper's second decomposition strategy, §III-C), each
//! carrying the fields it must fetch and its optimization cost.

use crate::catalog::Catalog;
use crate::imaging::Survey;
use crate::prng::Rng;

/// One unit of schedulable work (one light source).
#[derive(Clone, Debug)]
pub struct Task {
    /// catalog index (tasks are issued in catalog = Hilbert order)
    pub source: usize,
    /// field ids whose images this task needs
    pub fields: Vec<usize>,
    /// optimization wall time, seconds
    pub cost: f64,
}

/// The full workload plus the image inventory.
#[derive(Clone, Debug)]
pub struct Workload {
    pub tasks: Vec<Task>,
    /// bytes of each field's image data (5 bands)
    pub field_bytes: Vec<f64>,
}

impl Workload {
    pub fn total_cost(&self) -> f64 {
        self.tasks.iter().map(|t| t.cost).sum()
    }

    pub fn n_tasks(&self) -> usize {
        self.tasks.len()
    }
}

/// How per-source optimization cost is obtained.
#[derive(Clone, Debug)]
pub enum CostModel {
    /// Lognormal fit of the paper's description (§III-C): "anywhere from
    /// one second to over two minutes, with most sources taking less
    /// than five seconds", inflated by source crowding.
    Calibrated {
        /// median seconds for an isolated source
        median: f64,
        /// lognormal sigma
        sigma: f64,
        /// multiplicative cost per neighbor
        neighbor_factor: f64,
    },
    /// Fixed cost (unit tests / analytic checks).
    Fixed(f64),
}

impl Default for CostModel {
    fn default() -> Self {
        CostModel::Calibrated { median: 3.0, sigma: 0.7, neighbor_factor: 0.25 }
    }
}

impl CostModel {
    pub fn sample(&self, n_neighbors: usize, rng: &mut Rng) -> f64 {
        match self {
            CostModel::Fixed(c) => *c,
            CostModel::Calibrated { median, sigma, neighbor_factor } => {
                let base = rng.lognormal(median.ln(), *sigma);
                let crowd = 1.0 + neighbor_factor * n_neighbors as f64;
                (base * crowd).clamp(1.0, 130.0)
            }
        }
    }
}

/// Paper image scale: "an image is stored as a collection of five files
/// that are roughly 60 MB in aggregate" but "each image is roughly 120 MB
/// in size" in memory (§VI-B); we use the in-memory figure.
pub const FIELD_BYTES_PAPER: f64 = 120e6;

/// Build a workload from a catalog + survey layout. `neighbor_radius` is
/// the crowding radius in pixels used by the cost model.
pub fn build_workload(
    catalog: &Catalog,
    survey: &Survey,
    cost: &CostModel,
    field_bytes: f64,
    neighbor_radius: f64,
    seed: u64,
) -> Workload {
    let mut rng = Rng::new(seed);
    let margin = 0.0;
    let tasks = catalog
        .entries
        .iter()
        .map(|e| {
            let fields: Vec<usize> = survey
                .fields_containing(e.pos, margin)
                .iter()
                .map(|f| f.id)
                .collect();
            let n_neighbors = catalog.neighbors_within(e.pos, neighbor_radius, e.id).len();
            Task { source: e.id, fields, cost: cost.sample(n_neighbors, &mut rng) }
        })
        .collect();
    Workload { tasks, field_bytes: vec![field_bytes; survey.fields.len()] }
}

/// A synthetic workload without a catalog (scaling studies at sizes where
/// building 300k catalog entries is unnecessary): spatial structure is
/// captured by mapping contiguous task ranges to contiguous fields.
pub fn synthetic_workload(
    n_tasks: usize,
    n_fields: usize,
    fields_per_task: usize,
    cost: &CostModel,
    field_bytes: f64,
    seed: u64,
) -> Workload {
    let mut rng = Rng::new(seed);
    let tasks = (0..n_tasks)
        .map(|i| {
            // tasks are spatially ordered: nearby tasks share fields
            let base = (i * n_fields) / n_tasks.max(1);
            let fields = (0..fields_per_task)
                .map(|k| (base + k) % n_fields.max(1))
                .collect();
            // crowding proxy: clustered regions get more neighbors
            let crowded = (i / 64) % 7 == 0;
            let n_neighbors = if crowded { (rng.below(6) + 2) as usize } else { rng.below(2) as usize };
            Task { source: i, fields, cost: cost.sample(n_neighbors, &mut rng) }
        })
        .collect();
    Workload { tasks, field_bytes: vec![field_bytes; n_fields] }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::catalog::noisy_catalog;
    use crate::imaging::SurveyConfig;
    use crate::sky::{generate, SkyConfig};

    #[test]
    fn calibrated_costs_match_paper_description() {
        let cm = CostModel::default();
        let mut rng = Rng::new(1);
        let costs: Vec<f64> = (0..20_000).map(|_| cm.sample(0, &mut rng)).collect();
        let under_5s = costs.iter().filter(|&&c| c < 5.0).count() as f64 / costs.len() as f64;
        let max = costs.iter().cloned().fold(0.0, f64::max);
        let min = costs.iter().cloned().fold(f64::MAX, f64::min);
        assert!(under_5s > 0.5, "most sources under 5s: {under_5s}");
        assert!(min >= 1.0, "min {min}");
        assert!(max > 20.0 && max <= 130.0, "heavy tail up to ~2 min: {max}");
    }

    #[test]
    fn crowding_increases_cost() {
        let cm = CostModel::default();
        let mut rng = Rng::new(2);
        let lonely: f64 = (0..5000).map(|_| cm.sample(0, &mut rng)).sum::<f64>() / 5000.0;
        let crowded: f64 = (0..5000).map(|_| cm.sample(6, &mut rng)).sum::<f64>() / 5000.0;
        assert!(crowded > 1.8 * lonely, "{crowded} vs {lonely}");
    }

    #[test]
    fn workload_from_catalog_links_fields() {
        let u = generate(&SkyConfig { n_sources: 150, ..Default::default() });
        let mut rng = Rng::new(3);
        let cat = noisy_catalog(&u.sources, u.width, u.height, &mut rng, 0.5, 0.2);
        let survey = crate::imaging::Survey::layout(SurveyConfig {
            n_epochs: 2,
            ..Default::default()
        });
        let w = build_workload(&cat, &survey, &CostModel::Fixed(1.0), 120e6, 40.0, 7);
        assert_eq!(w.n_tasks(), 150);
        // every task sees at least one field (interior sources see >= 2 epochs)
        let with_fields = w.tasks.iter().filter(|t| !t.fields.is_empty()).count();
        assert!(with_fields > 140, "{with_fields}");
        let multi_epoch = w.tasks.iter().filter(|t| t.fields.len() >= 2).count();
        assert!(multi_epoch > 100, "overlap should be common: {multi_epoch}");
    }

    #[test]
    fn synthetic_workload_locality() {
        let w = synthetic_workload(1000, 50, 2, &CostModel::Fixed(1.0), 120e6, 1);
        assert_eq!(w.n_tasks(), 1000);
        // adjacent tasks mostly share their field set
        let mut shared = 0;
        for i in 1..1000 {
            if w.tasks[i].fields == w.tasks[i - 1].fields {
                shared += 1;
            }
        }
        assert!(shared > 900, "{shared}");
    }
}
