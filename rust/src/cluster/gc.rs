//! Emulation of Julia's serial stop-the-world garbage collector
//! (paper §VI, §VIII-A).
//!
//! Rust has no GC; to reproduce the paper's runtime-breakdown figures —
//! and to quantify what removing the GC buys (an ablation §VIII-A begs
//! for) — the simulator carries an explicit allocator model: every task
//! allocates, a process-wide collection triggers past a heap threshold,
//! and all threads of the process must reach a safepoint (finish their
//! current task) before the serial collector runs. That barrier is what
//! makes GC cost grow with thread count (Amdahl, §VI-A) and with job
//! duration (§VI-C).

#[derive(Clone, Debug)]
pub struct GcConfig {
    /// bytes allocated per optimized source (Julia temporaries)
    pub alloc_per_task: f64,
    /// heap size that triggers a collection, bytes
    pub heap_limit: f64,
    /// fixed pause per collection, seconds
    pub pause_base: f64,
    /// additional pause per heap byte, seconds/byte
    pub pause_per_byte: f64,
    /// fraction of the heap retained (live) after collection
    pub retained_frac: f64,
    /// slow heap growth per collection cycle (long-job effect §VI-C):
    /// the retained fraction grows by this much per cycle, capped at 0.8
    pub retained_growth: f64,
}

impl Default for GcConfig {
    fn default() -> Self {
        // Calibrated so that a 4-thread process at ~5 s/task spends
        // ~15-25% of runtime in GC and a 16-thread process >1/3 (Fig 3).
        GcConfig {
            alloc_per_task: 100e6,
            heap_limit: 2e9,
            pause_base: 0.3,
            pause_per_byte: 0.6e-9,
            retained_frac: 0.2,
            retained_growth: 0.005,
        }
    }
}

/// Per-process allocator state.
#[derive(Clone, Debug, Default)]
pub struct HeapState {
    pub heap: f64,
    pub cycles: u64,
    pub retained: f64,
}

impl HeapState {
    pub fn new(cfg: &GcConfig) -> HeapState {
        HeapState { heap: 0.0, cycles: 0, retained: cfg.retained_frac }
    }

    /// Record a task's allocations; returns true if GC should trigger.
    pub fn allocate(&mut self, cfg: &GcConfig, bytes: f64) -> bool {
        self.heap += bytes;
        self.heap >= cfg.heap_limit
    }

    /// Perform a collection; returns the pause duration.
    pub fn collect(&mut self, cfg: &GcConfig) -> f64 {
        let pause = cfg.pause_base + cfg.pause_per_byte * self.heap;
        self.heap *= self.retained;
        self.cycles += 1;
        // long-running jobs retain more (fragmentation/growth, §VI-C)
        self.retained = (self.retained + cfg.retained_growth).min(0.8);
        pause
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn triggers_at_limit() {
        let cfg = GcConfig::default();
        let mut h = HeapState::new(&cfg);
        let mut triggered = false;
        for _ in 0..100 {
            if h.allocate(&cfg, cfg.alloc_per_task) {
                triggered = true;
                break;
            }
        }
        assert!(triggered);
        // 2e9 / alloc_per_task tasks per cycle
        let want = (cfg.heap_limit / cfg.alloc_per_task).ceil();
        assert!((h.heap / cfg.alloc_per_task - want).abs() < 2.0);
    }

    #[test]
    fn collect_shrinks_heap_and_pauses() {
        let cfg = GcConfig::default();
        let mut h = HeapState::new(&cfg);
        while !h.allocate(&cfg, cfg.alloc_per_task) {}
        let before = h.heap;
        let pause = h.collect(&cfg);
        assert!(h.heap < 0.5 * before);
        assert!(pause > cfg.pause_base);
        assert!(pause < 5.0, "pause {pause}");
        assert_eq!(h.cycles, 1);
    }

    #[test]
    fn retained_fraction_grows_over_cycles() {
        let cfg = GcConfig::default();
        let mut h = HeapState::new(&cfg);
        let r0 = h.retained;
        for _ in 0..20 {
            while !h.allocate(&cfg, cfg.alloc_per_task) {}
            h.collect(&cfg);
        }
        assert!(h.retained > r0);
        assert!(h.retained <= 0.8);
    }
}
