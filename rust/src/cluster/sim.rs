//! The discrete-event cluster simulator: phases 1–3 of §III-D over
//! nodes × processes × threads, with Dtree scheduling, global-array
//! image fetches over the modeled fabric, per-process image caches, and
//! optional serial-GC emulation.

use crate::dtree::{Dtree, DtreeConfig};
use crate::ga::{Fabric, FabricConfig, GlobalArray, LruCache};
use crate::metrics::{Breakdown, Component, Stats};

use super::event::EventQueue;
use super::gc::{GcConfig, HeapState};
use super::workload::Workload;

#[derive(Clone, Debug)]
pub struct ClusterConfig {
    pub nodes: usize,
    pub procs_per_node: usize,
    pub threads_per_proc: usize,
    pub fabric: FabricConfig,
    /// None = native Rust (no GC); Some = Julia serial-GC emulation
    pub gc: Option<GcConfig>,
    pub dtree: DtreeConfig,
    /// network latency per scheduler hop, seconds
    pub sched_hop_latency: f64,
    /// fixed local scheduler overhead per request, seconds
    pub sched_base: f64,
    /// per-process image cache capacity, bytes
    pub cache_bytes: f64,
    /// aggregate parallel-filesystem bandwidth for phase 1, B/s
    pub disk_bw: f64,
}

impl Default for ClusterConfig {
    fn default() -> Self {
        ClusterConfig {
            nodes: 1,
            // paper §VI-A: "A single Cori Phase I node has 32 cores; we
            // run 8 processes per node" with 4 threads each
            procs_per_node: 8,
            threads_per_proc: 4,
            fabric: FabricConfig::default(),
            gc: Some(GcConfig::default()),
            dtree: DtreeConfig::default(),
            sched_hop_latency: 50e-6,
            sched_base: 20e-6,
            cache_bytes: 8e9,
            disk_bw: 700e9, // Cori Lustre aggregate (§V)
        }
    }
}

/// Results of one simulated run.
#[derive(Clone, Debug)]
pub struct RunReport {
    /// end-to-end simulated wall time, seconds
    pub makespan: f64,
    /// thread-seconds per runtime component (sums to ~threads*makespan)
    pub breakdown: Breakdown,
    /// the paper's headline metric
    pub sources_per_sec: f64,
    pub n_tasks: usize,
    pub nodes: usize,
    pub total_threads: usize,
    /// image-cache hit rate across all processes
    pub cache_hit_rate: f64,
    /// bytes moved over the fabric
    pub fabric_bytes: f64,
    /// GC collections across all processes
    pub gc_cycles: u64,
    /// distribution of per-task total latency
    pub task_stats: Stats,
}

impl RunReport {
    /// Paper-style one-line summary (+ per-task latency quantiles).
    pub fn summary(&self) -> String {
        let q = self.task_stats.quantiles(&[0.50, 0.99]);
        format!(
            "nodes={} threads={} tasks={} makespan={:.1}s src/s={:.2} task-p50={:.3}s task-p99={:.3}s | {}",
            self.nodes,
            self.total_threads,
            self.n_tasks,
            self.makespan,
            self.sources_per_sec,
            q[0],
            q[1],
            self.breakdown.table_row()
        )
    }
}

struct ProcState {
    batch: std::collections::VecDeque<usize>,
    cache: LruCache,
    heap: HeapState,
    gc_pending: bool,
    parked: Vec<(usize, f64)>,
    active_threads: usize,
    done_threads: usize,
}

#[allow(clippy::too_many_arguments)]
fn run_gc(
    proc: &mut ProcState,
    gcc: &GcConfig,
    now: f64,
    breakdown: &mut Breakdown,
    queue: &mut EventQueue,
    p: usize,
    gc_cycles: &mut u64,
) {
    let pause = proc.heap.collect(gcc);
    *gc_cycles += 1;
    let gc_end = now + pause;
    let parked = std::mem::take(&mut proc.parked);
    for (th, park_t) in parked {
        breakdown.add(Component::Gc, gc_end - park_t);
        queue.push(gc_end, p, th);
    }
    proc.gc_pending = false;
}

/// Run the three-phase algorithm over the workload.
pub fn simulate(cfg: &ClusterConfig, workload: &Workload) -> RunReport {
    let nprocs = cfg.nodes * cfg.procs_per_node;
    let tpp = cfg.threads_per_proc;
    let total_threads = nprocs * tpp;
    let node_of = |p: usize| p / cfg.procs_per_node;

    let ga = GlobalArray::round_robin(workload.field_bytes.clone(), nprocs);
    let mut fabric = Fabric::new(cfg.fabric.clone(), cfg.nodes);
    let mut dtree = Dtree::new(cfg.dtree.clone(), nprocs, workload.tasks.len());
    let mut breakdown = Breakdown::new();
    let mut task_stats = Stats::new();

    // ---------------- phase 1+2: load images & catalog ----------------
    // Processes read their chunks from the parallel FS concurrently; all
    // processes synchronize before optimization (any image may be needed
    // anywhere). Catalog load is folded in (it is tiny).
    let per_proc_bw = cfg.disk_bw / nprocs as f64;
    let phase1_end = ga
        .bytes_per_proc()
        .iter()
        .map(|b| 0.05 + b / per_proc_bw)
        .fold(0.0f64, f64::max);
    breakdown.add(Component::ImageLoad, phase1_end * total_threads as f64);

    // ---------------- phase 3: optimize sources ----------------
    let gc_cfg = cfg.gc.clone();
    let mut procs: Vec<ProcState> = (0..nprocs)
        .map(|_| ProcState {
            batch: Default::default(),
            cache: LruCache::new(cfg.cache_bytes),
            heap: gc_cfg.as_ref().map(HeapState::new).unwrap_or_default(),
            gc_pending: false,
            parked: Vec::new(),
            active_threads: tpp,
            done_threads: 0,
        })
        .collect();

    let mut queue = EventQueue::new();
    for p in 0..nprocs {
        for t in 0..tpp {
            queue.push(phase1_end, p, t);
        }
    }

    let mut finish_time = vec![phase1_end; total_threads];
    let mut gc_cycles = 0u64;
    let mut makespan = phase1_end;

    while let Some(ev) = queue.pop() {
        let now = ev.time;
        makespan = makespan.max(now);
        let p = ev.proc;

        // GC barrier: park until every active thread reaches a safepoint
        if procs[p].gc_pending {
            procs[p].parked.push((ev.thread, now));
            if procs[p].parked.len() == procs[p].active_threads {
                run_gc(
                    &mut procs[p],
                    gc_cfg.as_ref().expect("gc_pending requires gc config"),
                    now,
                    &mut breakdown,
                    &mut queue,
                    p,
                    &mut gc_cycles,
                );
            }
            continue;
        }

        // acquire work
        let mut t_clock = now;
        if procs[p].batch.is_empty() {
            match dtree.request(p) {
                Some(grant) => {
                    let delay = cfg.sched_base + grant.hops as f64 * cfg.sched_hop_latency;
                    breakdown.add(Component::Scheduling, delay);
                    t_clock += delay;
                    for i in grant.range.first..grant.range.last {
                        procs[p].batch.push_back(i);
                    }
                }
                None => {
                    // no more work anywhere: thread terminates
                    procs[p].active_threads -= 1;
                    procs[p].done_threads += 1;
                    finish_time[p * tpp + ev.thread] = t_clock;
                    // a pending GC may now be unblocked (the terminated
                    // thread no longer has to reach a safepoint)
                    if procs[p].gc_pending
                        && procs[p].active_threads > 0
                        && procs[p].parked.len() == procs[p].active_threads
                    {
                        run_gc(
                            &mut procs[p],
                            gc_cfg.as_ref().expect("gc_pending requires gc config"),
                            t_clock,
                            &mut breakdown,
                            &mut queue,
                            p,
                            &mut gc_cycles,
                        );
                    }
                    continue;
                }
            }
        }
        let task_idx = procs[p].batch.pop_front().expect("batch nonempty");
        let task = &workload.tasks[task_idx];
        let t_start = t_clock;

        // image fetches through cache + global array
        for &field in &task.fields {
            if procs[p].cache.contains(field as u64) {
                continue;
            }
            let bytes = ga.bytes_of(field);
            let owner = ga.owner_of(field);
            let done = fabric.get(t_clock, bytes, node_of(owner), node_of(p));
            breakdown.add(Component::GaFetch, done - t_clock);
            t_clock = done;
            procs[p].cache.insert(field as u64, bytes);
        }

        // optimize
        breakdown.add(Component::Optimize, task.cost);
        t_clock += task.cost;
        task_stats.push(t_clock - t_start);

        // allocations → possible GC trigger
        if let Some(gcc) = &gc_cfg {
            if procs[p].heap.allocate(gcc, gcc.alloc_per_task) {
                procs[p].gc_pending = true;
            }
        }

        queue.push(t_clock, p, ev.thread);
    }

    // drain: any still-pending GC parks can be discarded (work is done)
    for p in &procs {
        debug_assert!(p.batch.is_empty());
    }

    // load imbalance: idle tail per thread
    for &ft in &finish_time {
        breakdown.add(Component::LoadImbalance, (makespan - ft).max(0.0));
    }

    let (mut hits, mut misses) = (0u64, 0u64);
    for p in &procs {
        hits += p.cache.hits;
        misses += p.cache.misses;
    }

    RunReport {
        makespan,
        sources_per_sec: workload.tasks.len() as f64 / makespan.max(1e-9),
        n_tasks: workload.tasks.len(),
        nodes: cfg.nodes,
        total_threads,
        cache_hit_rate: if hits + misses == 0 { 0.0 } else { hits as f64 / (hits + misses) as f64 },
        fabric_bytes: fabric.bytes_moved,
        gc_cycles,
        breakdown,
        task_stats,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::workload::{synthetic_workload, CostModel};

    fn wl(n_tasks: usize, n_fields: usize) -> Workload {
        synthetic_workload(n_tasks, n_fields, 2, &CostModel::Fixed(1.0), 120e6, 1)
    }

    fn no_gc(nodes: usize, threads: usize) -> ClusterConfig {
        ClusterConfig {
            nodes,
            procs_per_node: 1,
            threads_per_proc: threads,
            gc: None,
            ..Default::default()
        }
    }

    #[test]
    fn single_thread_makespan_is_total_cost() {
        let w = wl(50, 4);
        let r = simulate(&no_gc(1, 1), &w);
        // 50 tasks x 1s + fetches + load; fetches are few (cache) and fast
        assert!(r.makespan >= 50.0);
        assert!(r.makespan < 52.0, "{}", r.makespan);
        assert_eq!(r.n_tasks, 50);
    }

    #[test]
    fn threads_scale_throughput_without_gc() {
        let w = wl(256, 4);
        let r1 = simulate(&no_gc(1, 1), &w);
        let r4 = simulate(&no_gc(1, 4), &w);
        let speedup = r1.makespan / r4.makespan;
        assert!(speedup > 3.5, "speedup {speedup}");
    }

    #[test]
    fn gc_adds_overhead_and_limits_thread_scaling() {
        // paper-scale tasks (~5 s); GC calibration targets Fig 3 shares
        let w = synthetic_workload(512, 4, 2, &CostModel::Fixed(5.0), 120e6, 1);
        let mk = |threads: usize, gc: bool| ClusterConfig {
            nodes: 1,
            procs_per_node: 1,
            threads_per_proc: threads,
            gc: if gc { Some(GcConfig::default()) } else { None },
            ..Default::default()
        };
        let r4 = simulate(&mk(4, true), &w);
        let r16 = simulate(&mk(16, true), &w);
        let frac4 = r4.breakdown.fraction(Component::Gc);
        let frac16 = r16.breakdown.fraction(Component::Gc);
        // Fig 3 shape: noticeable at 4 threads, much worse at 16
        assert!((0.05..0.40).contains(&frac4), "gc share at 4 threads: {frac4}");
        assert!(frac16 > 1.3 * frac4, "gc share grows with threads: {frac4} -> {frac16}");
        // Fig 3: 16-thread efficiency clearly below ideal
        let r16_nogc = simulate(&mk(16, false), &w);
        assert!(r16.makespan > 1.15 * r16_nogc.makespan);
    }

    #[test]
    fn all_tasks_processed_exactly_once() {
        let w = wl(333, 7);
        let r = simulate(&no_gc(2, 3), &w);
        assert_eq!(r.task_stats.n, 333);
        assert_eq!(r.n_tasks, 333);
    }

    #[test]
    fn deterministic() {
        let w = wl(200, 5);
        let a = simulate(&no_gc(2, 2), &w);
        let b = simulate(&no_gc(2, 2), &w);
        assert_eq!(a.makespan, b.makespan);
        assert_eq!(a.breakdown, b.breakdown);
    }

    #[test]
    fn ga_fetch_share_grows_with_node_count() {
        // weak scaling: tasks/node fixed; fetch share must rise
        let mk = |nodes: usize| {
            let w = synthetic_workload(
                nodes * 64,
                nodes * 16,
                3,
                &CostModel::Fixed(2.0),
                120e6,
                1,
            );
            let c = ClusterConfig {
                nodes,
                procs_per_node: 4,
                threads_per_proc: 4,
                gc: None,
                cache_bytes: 360e6, // small cache → fetch traffic
                ..Default::default()
            };
            simulate(&c, &w)
        };
        let small = mk(2);
        let large = mk(32);
        let fs = small.breakdown.fraction(Component::GaFetch);
        let fl = large.breakdown.fraction(Component::GaFetch);
        assert!(fl > fs, "fetch share must grow: {fs} -> {fl}");
    }

    #[test]
    fn imbalance_appears_with_heavy_tail() {
        let heavy = synthetic_workload(64, 4, 1, &CostModel::default(), 120e6, 3);
        let r = simulate(&no_gc(4, 4), &heavy);
        assert!(r.breakdown.get(Component::LoadImbalance) > 0.0);
    }

    #[test]
    fn cache_hits_reduce_fabric_traffic() {
        let w = wl(256, 4);
        let big_cache = ClusterConfig { cache_bytes: 8e9, gc: None, ..no_gc(2, 2) };
        let no_cache = ClusterConfig { cache_bytes: 1.0, gc: None, ..no_gc(2, 2) };
        let rb = simulate(&big_cache, &w);
        let rn = simulate(&no_cache, &w);
        assert!(rb.fabric_bytes < 0.25 * rn.fabric_bytes, "{} vs {}", rb.fabric_bytes, rn.fabric_bytes);
        assert!(rb.cache_hit_rate > 0.8);
    }
}
