//! Table I reproduction at example scale: Photo vs Celeste on a synthetic
//! Stripe 82 (30 repeated exposures, saturation injected).
//!
//!   make artifacts && cargo run --release --example stripe82_validation

fn main() -> anyhow::Result<()> {
    let quick = std::env::args().any(|a| a == "--full").then_some(false).unwrap_or(true);
    let v = celeste::experiments::table1::run(quick, 1)?;
    celeste::experiments::save_result("table1_example", &v)?;
    Ok(())
}
