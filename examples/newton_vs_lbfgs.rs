//! The optimizer story of §III-B: trust-region Newton vs L-BFGS on real
//! per-source problems against the compiled artifacts.
//!
//!   make artifacts && cargo run --release --example newton_vs_lbfgs

fn main() -> anyhow::Result<()> {
    let quick = !std::env::args().any(|a| a == "--full");
    let v = celeste::experiments::newton_lbfgs::run(quick)?;
    celeste::experiments::save_result("newton_vs_lbfgs_example", &v)?;
    Ok(())
}
