//! Quickstart: the end-to-end driver (DESIGN.md: "end-to-end validation").
//!
//! Generates a small synthetic survey *from the Celeste generative
//! model*, runs the full three-phase inference pipeline against the
//! compiled artifacts, and reports accuracy against the known ground
//! truth — including the posterior uncertainties that are the point of
//! the Bayesian approach.
//!
//!   make artifacts && cargo run --release --example quickstart

use celeste::catalog::noisy_catalog;
use celeste::coordinator::{render_survey, run_inference, InferenceConfig};
use celeste::imaging::{Survey, SurveyConfig};
use celeste::model::Prior;
use celeste::prng::Rng;
use celeste::sky::{generate, SkyConfig};

fn main() -> anyhow::Result<()> {
    let n_sources = std::env::args()
        .nth(1)
        .and_then(|s| s.parse().ok())
        .unwrap_or(40);

    // --- a small sky and a 2-epoch survey over it ---
    let side = 320.0;
    let sky = generate(&SkyConfig {
        width: side,
        height: side,
        n_sources,
        flux_star: (6.3, 0.7),
        flux_gal: (6.8, 0.7),
        seed: 7,
        ..Default::default()
    });
    let survey = Survey::layout(SurveyConfig {
        sky_width: side,
        sky_height: side,
        field_w: side as usize,
        field_h: side as usize,
        n_epochs: 2,
        jitter: 0.0,
        overlap: 0.0, // one field per epoch: 2 patches per source
        ..Default::default()
    });
    let fields = render_survey(&survey, &sky.sources, 11);
    println!(
        "synthesized {} sources over {} exposures x 5 bands",
        n_sources,
        fields.len()
    );

    // --- a noisy 'previous survey' catalog to initialize from ---
    let mut rng = Rng::new(13);
    let catalog = noisy_catalog(&sky.sources, side, side, &mut rng, 0.8, 0.3);
    let prior = Prior::fit(&sky.sources);

    // --- inference ---
    let cfg = InferenceConfig::default();
    let (inferred, stats) = run_inference(&fields, &catalog, &prior, &cfg)?;
    println!(
        "inference: {}/{} converged, mean {:.1} Newton iterations, {:.2} sources/sec",
        stats.converged, stats.sources, stats.iters.mean(), stats.sources_per_sec
    );

    // --- accuracy vs the known truth ---
    let mut pos_err = 0.0;
    let mut mag_err = 0.0;
    let mut class_ok = 0usize;
    let mut cal_hits = 0usize; // |log flux error| < 2 posterior sd
    for s in &inferred {
        // nearest true source
        let t = sky
            .sources
            .iter()
            .min_by(|a, b| {
                let da = (a.pos.0 - s.pos.0).powi(2) + (a.pos.1 - s.pos.1).powi(2);
                let db = (b.pos.0 - s.pos.0).powi(2) + (b.pos.1 - s.pos.1).powi(2);
                da.partial_cmp(&db).unwrap()
            })
            .unwrap();
        pos_err += ((t.pos.0 - s.pos.0).powi(2) + (t.pos.1 - s.pos.1).powi(2)).sqrt();
        mag_err += (2.5 * (s.est.flux_r / t.flux_r).log10()).abs();
        class_ok += ((s.est.p_gal > 0.5) == t.is_galaxy) as usize;
        let z = (s.est.flux_r.ln() - t.flux_r.ln()).abs() / s.flux_logsd.max(1e-6);
        cal_hits += (z < 2.0) as usize;
    }
    let n = inferred.len().max(1) as f64;
    println!("mean position error : {:.3} px", pos_err / n);
    println!("mean |Δmag|         : {:.3}", mag_err / n);
    println!("classification acc  : {:.1}%", 100.0 * class_ok as f64 / n);
    println!(
        "flux coverage       : {:.1}% of true fluxes inside ±2 posterior SD",
        100.0 * cal_hits as f64 / n
    );
    println!("(uncertainty quantification is what heuristics cannot provide — §II)");
    Ok(())
}
