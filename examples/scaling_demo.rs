//! Scaling demo: the simulated-cluster experiments behind Figs 3–6 —
//! thread scaling with the serial-GC emulation, then weak and strong
//! multi-node scaling over the modeled Aries-like fabric.
//!
//!   cargo run --release --example scaling_demo [-- --full]

fn main() {
    let quick = !std::env::args().any(|a| a == "--full");
    let f3 = celeste::experiments::fig3::run(quick);
    println!();
    let f4 = celeste::experiments::fig45::run_weak(quick);
    println!();
    let f5 = celeste::experiments::fig45::run_strong(quick);
    let _ = celeste::experiments::save_result("scaling_demo_fig3", &f3);
    let _ = celeste::experiments::save_result("scaling_demo_fig4", &f4);
    let _ = celeste::experiments::save_result("scaling_demo_fig5", &f5);
}
