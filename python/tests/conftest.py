"""Shared fixtures: synthetic patches drawn from the generative model."""

import os
import sys

import numpy as np

sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))

from compile import constants as C  # noqa: E402


def default_psf(dtype=np.float32):
    """A plausible 2-component per-band PSF (weights sum to 1)."""
    psf = np.zeros((C.N_BANDS, C.K_PSF, C.PSF_PARAMS), dtype)
    for b in range(C.N_BANDS):
        width = 1.0 + 0.1 * b  # seeing varies by band
        psf[b, 0] = [0.7, 0.0, 0.0, width, 0.05, width]
        psf[b, 1] = [0.3, 0.1, -0.1, 2.5 * width, -0.1, 2.5 * width]
    return psf


def default_prior(dtype=np.float32):
    prior = np.zeros(C.PRIOR_DIM, dtype)
    prior[C.P_A] = 0.3
    prior[C.P_FLUX_STAR : C.P_FLUX_STAR + 2] = [4.0, 2.0]
    prior[C.P_FLUX_GAL : C.P_FLUX_GAL + 2] = [4.5, 2.0]
    prior[C.P_COLOR_MEAN_STAR : C.P_COLOR_MEAN_STAR + 4] = [0.5, 0.4, 0.2, 0.1]
    prior[C.P_COLOR_MEAN_GAL : C.P_COLOR_MEAN_GAL + 4] = [0.8, 0.5, 0.3, 0.2]
    prior[C.P_COLOR_VAR_STAR : C.P_COLOR_VAR_STAR + 4] = 0.04
    prior[C.P_COLOR_VAR_GAL : C.P_COLOR_VAR_GAL + 4] = 0.04
    return prior


def random_theta(rng, dtype=np.float32):
    """A θ in the plausible region of parameter space."""
    t = np.zeros(C.DIM, dtype)
    t[C.I_A] = rng.normal(0.0, 1.0)
    t[C.I_LOC : C.I_LOC + 2] = rng.normal(0.0, 1.0, 2)
    t[C.I_FLUX_STAR : C.I_FLUX_STAR + 2] = [rng.normal(4.0, 0.5), -1.0]
    t[C.I_FLUX_GAL : C.I_FLUX_GAL + 2] = [rng.normal(4.5, 0.5), -1.0]
    t[C.I_COLOR_MEAN_STAR : C.I_COLOR_MEAN_STAR + 4] = rng.normal(0.4, 0.2, 4)
    t[C.I_COLOR_MEAN_GAL : C.I_COLOR_MEAN_GAL + 4] = rng.normal(0.5, 0.2, 4)
    t[C.I_COLOR_VAR_STAR : C.I_COLOR_VAR_STAR + 4] = -2.0
    t[C.I_COLOR_VAR_GAL : C.I_COLOR_VAR_GAL + 4] = -2.0
    t[C.I_SHAPE : C.I_SHAPE + 4] = [
        rng.normal(0.0, 0.5),
        rng.normal(0.5, 0.5),
        rng.uniform(-1.5, 1.5),
        rng.normal(0.5, 0.3),
    ]
    return t


def synthetic_patch(rng, theta=None, dtype=np.float32):
    """Draw a (pixels, bg, mask, psf, gain) tuple from the model itself."""
    import jax.numpy as jnp
    from compile import model

    psf = default_psf(dtype)
    gain = np.ones(C.N_BANDS, dtype)
    bg = np.full((C.N_BANDS, C.PATCH, C.PATCH), 60.0, dtype)
    mask = np.ones_like(bg)
    if theta is None:
        theta = random_theta(rng, dtype)
    comps_s, comps_g, scal = model.build_inputs(jnp.asarray(theta), jnp.asarray(psf), jnp.asarray(gain))
    from compile.kernels import ref

    rate = np.array(
        [
            bg[b]
            + np.asarray(scal[b, 0] * ref.mog_eval(comps_s[b]))
            + np.asarray(scal[b, 1] * ref.mog_eval(comps_g[b]))
            for b in range(C.N_BANDS)
        ]
    )
    pixels = rng.poisson(rate).astype(dtype)
    return theta, pixels, bg, mask, psf, gain
