"""L1 kernel validation: Pallas vs the pure-jnp oracle (ref.py).

Hypothesis sweeps component counts, patch shapes, and parameter magnitudes;
every property asserts allclose against ref.py.
"""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

import jax.numpy as jnp

from compile import constants as C, model
from compile.kernels import mog_render, ref
from conftest import synthetic_patch, random_theta

RNG = np.random.default_rng(1234)


def make_comps(rng, k, spread=8.0):
    """Random positive-definite effective components on the patch."""
    comps = np.zeros((k, 6), np.float32)
    comps[:, 0] = rng.uniform(0.01, 1.0, k)  # w_eff
    comps[:, 1] = C.PATCH / 2 + rng.normal(0, spread, k)  # mx
    comps[:, 2] = C.PATCH / 2 + rng.normal(0, spread, k)  # my
    # precision = inverse of a random SPD covariance
    for i in range(k):
        a = rng.uniform(0.5, 4.0)
        b = rng.uniform(0.5, 4.0)
        c = rng.uniform(-0.5, 0.5) * np.sqrt(a * b)
        det = a * b - c * c
        comps[i, 3:6] = [b / det, -c / det, a / det]
    return comps


class TestRender:
    @settings(max_examples=12, deadline=None)
    @given(
        k=st.integers(1, 24),
        seed=st.integers(0, 2**31 - 1),
        hmul=st.integers(1, 4),
    )
    def test_matches_ref_shapes(self, k, seed, hmul):
        rng = np.random.default_rng(seed)
        comps = jnp.asarray(make_comps(rng, k))
        h = mog_render.TILE_H * hmul
        got = mog_render.render(comps, h=h, w=C.PATCH)
        want = ref.mog_eval(comps, h=h, w=C.PATCH)
        np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-7)

    def test_zero_weight_is_zero(self):
        comps = jnp.asarray(make_comps(RNG, 4)).at[:, 0].set(0.0)
        assert np.all(np.asarray(mog_render.render(comps)) == 0.0)

    def test_translation_equivariance(self):
        """Shifting every mean by one pixel shifts the image one pixel."""
        comps = make_comps(RNG, 5, spread=4.0)
        img0 = np.asarray(mog_render.render(jnp.asarray(comps)))
        comps2 = comps.copy()
        comps2[:, 1] += 1.0
        img1 = np.asarray(mog_render.render(jnp.asarray(comps2)))
        np.testing.assert_allclose(img1[:, 1:], img0[:, :-1], rtol=1e-4, atol=1e-6)

    def test_unit_mixture_integrates_to_one(self):
        """A normalized, well-contained mixture sums to ~1 over the patch."""
        comps = np.zeros((2, 6), np.float32)
        for i, (w, var) in enumerate([(0.6, 1.2), (0.4, 2.0)]):
            comps[i, 0] = w / (2 * np.pi * var)
            comps[i, 1] = comps[i, 2] = C.PATCH / 2
            comps[i, 3] = comps[i, 5] = 1 / var
        total = float(np.asarray(mog_render.render(jnp.asarray(comps))).sum())
        assert abs(total - 1.0) < 1e-3


class TestLikeBand:
    @settings(max_examples=8, deadline=None)
    @given(seed=st.integers(0, 2**31 - 1))
    def test_value_matches_ref(self, seed):
        rng = np.random.default_rng(seed)
        theta, pixels, bg, mask, psf, gain = synthetic_patch(rng)
        comps_s, comps_g, scal = model.build_inputs(
            jnp.asarray(theta), jnp.asarray(psf), jnp.asarray(gain)
        )
        for b in [0, C.REF_BAND, C.N_BANDS - 1]:
            got = mog_render.like_band(
                jnp.asarray(pixels[b]), jnp.asarray(bg[b]), jnp.asarray(mask[b]),
                comps_s[b], comps_g[b], scal[b],
            )
            want = ref.poisson_elbo_band(
                jnp.asarray(pixels[b]), jnp.asarray(bg[b]), jnp.asarray(mask[b]),
                ref.mog_eval(comps_s[b]), ref.mog_eval(comps_g[b]), scal[b],
            )
            np.testing.assert_allclose(got, want, rtol=2e-5)

    def test_mask_zeroes_contribution(self):
        rng = np.random.default_rng(7)
        theta, pixels, bg, mask, psf, gain = synthetic_patch(rng)
        comps_s, comps_g, scal = model.build_inputs(
            jnp.asarray(theta), jnp.asarray(psf), jnp.asarray(gain)
        )
        z = mog_render.like_band(
            jnp.asarray(pixels[0]), jnp.asarray(bg[0]),
            jnp.zeros_like(jnp.asarray(mask[0])), comps_s[0], comps_g[0], scal[0],
        )
        assert float(z) == 0.0


class TestManualGradient:
    """The kernel's hand-derived cotangents vs autodiff of the jnp oracle."""

    @settings(max_examples=6, deadline=None)
    @given(seed=st.integers(0, 2**31 - 1))
    def test_cotangents_match_autodiff(self, seed):
        import jax

        rng = np.random.default_rng(seed)
        theta, pixels, bg, mask, psf, gain = synthetic_patch(rng)
        comps_s, comps_g, scal = model.build_inputs(
            jnp.asarray(theta), jnp.asarray(psf), jnp.asarray(gain)
        )
        b = C.REF_BAND
        px, bgb, mk = map(jnp.asarray, (pixels[b], bg[b], mask[b]))

        def oracle(cs, cg, sc):
            return ref.poisson_elbo_band(
                px, bgb, mk, ref.mog_eval(cs), ref.mog_eval(cg), sc
            )

        ll, dcs, dcg, dscal = mog_render.like_grad_band(
            px, bgb, mk, comps_s[b], comps_g[b], scal[b]
        )
        want_ll = oracle(comps_s[b], comps_g[b], scal[b])
        gcs, gcg, gsc = jax.grad(oracle, argnums=(0, 1, 2))(
            comps_s[b], comps_g[b], scal[b]
        )
        np.testing.assert_allclose(ll, want_ll, rtol=2e-5)
        for got, want in [(dcs, gcs), (dcg, gcg), (dscal, gsc)]:
            got, want = np.asarray(got), np.asarray(want)
            denom = np.maximum(np.abs(want), 1e-2 * np.abs(want).max() + 1e-6)
            np.testing.assert_allclose(got / denom, want / denom, atol=2e-3)

    @settings(max_examples=6, deadline=None)
    @given(seed=st.integers(0, 2**31 - 1))
    def test_full_theta_grad_matches_ad_artifact(self, seed):
        rng = np.random.default_rng(seed)
        theta, pixels, bg, mask, psf, gain = map(
            jnp.asarray, synthetic_patch(rng)
        )
        f_ad, g_ad, _ = model.like_vgh(theta, pixels, bg, mask, psf, gain)
        f_pl, g_pl = mog_render.like_pallas_vg(
            theta, pixels, bg, mask, psf, gain
        )
        np.testing.assert_allclose(f_pl, f_ad, rtol=3e-5)
        scale = float(jnp.abs(g_ad).max())
        np.testing.assert_allclose(
            np.asarray(g_pl), np.asarray(g_ad), atol=3e-3 * scale, rtol=2e-3
        )
