"""L2 model validation: transforms, moments, KL properties, derivatives."""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

import jax
import jax.numpy as jnp

from compile import constants as C, model
from compile.kernels import ref
from conftest import default_prior, default_psf, random_theta, synthetic_patch

RNG = np.random.default_rng(99)


def prior_matching_theta(prior):
    """θ whose variational factors equal the prior exactly."""
    t = np.zeros(C.DIM, np.float32)
    pg = prior[C.P_A]
    t[C.I_A] = np.log(pg / (1 - pg))
    t[C.I_FLUX_STAR] = prior[C.P_FLUX_STAR]
    t[C.I_FLUX_STAR + 1] = np.log(prior[C.P_FLUX_STAR + 1])
    t[C.I_FLUX_GAL] = prior[C.P_FLUX_GAL]
    t[C.I_FLUX_GAL + 1] = np.log(prior[C.P_FLUX_GAL + 1])
    t[C.I_COLOR_MEAN_STAR : C.I_COLOR_MEAN_STAR + 4] = prior[
        C.P_COLOR_MEAN_STAR : C.P_COLOR_MEAN_STAR + 4
    ]
    t[C.I_COLOR_MEAN_GAL : C.I_COLOR_MEAN_GAL + 4] = prior[
        C.P_COLOR_MEAN_GAL : C.P_COLOR_MEAN_GAL + 4
    ]
    t[C.I_COLOR_VAR_STAR : C.I_COLOR_VAR_STAR + 4] = np.log(
        prior[C.P_COLOR_VAR_STAR : C.P_COLOR_VAR_STAR + 4]
    )
    t[C.I_COLOR_VAR_GAL : C.I_COLOR_VAR_GAL + 4] = np.log(
        prior[C.P_COLOR_VAR_GAL : C.P_COLOR_VAR_GAL + 4]
    )
    # shape entries at the shape-prior means (zero penalty)
    t[C.I_SHAPE] = C.SHAPE_PRIOR_PDEV[0]
    t[C.I_SHAPE + 1] = C.SHAPE_PRIOR_AXIS[0]
    t[C.I_SHAPE + 3] = C.SHAPE_PRIOR_SCALE[0]
    return t


class TestKL:
    def test_nonnegative(self):
        prior = jnp.asarray(default_prior())
        for _ in range(20):
            t = jnp.asarray(random_theta(RNG))
            # subtract ridge and shape prior, which are not the KL proper
            rd = np.concatenate(
                [t[C.I_LOC : C.I_LOC + 2], t[C.I_SHAPE : C.I_SHAPE + 4]]
            )
            ridge = 0.5 * C.RIDGE * float(np.sum(rd**2))
            gam_g = 1.0 / (1.0 + np.exp(-float(t[C.I_A])))
            sp = gam_g * sum(
                0.5 * (float(t[C.I_SHAPE + o]) - mv[0]) ** 2 / mv[1]
                for o, mv in [
                    (0, C.SHAPE_PRIOR_PDEV),
                    (1, C.SHAPE_PRIOR_AXIS),
                    (3, C.SHAPE_PRIOR_SCALE),
                ]
            )
            assert float(model.elbo_kl(t, prior)) - ridge - sp >= -1e-6

    def test_zero_at_prior(self):
        prior = default_prior()
        t = jnp.asarray(prior_matching_theta(prior))
        assert float(model.elbo_kl(t, jnp.asarray(prior))) < 1e-4

    def test_increases_away_from_prior(self):
        prior = default_prior()
        t0 = prior_matching_theta(prior)
        k0 = float(model.elbo_kl(jnp.asarray(t0), jnp.asarray(prior)))
        t1 = t0.copy()
        t1[C.I_FLUX_STAR] += 2.0
        k1 = float(model.elbo_kl(jnp.asarray(t1), jnp.asarray(prior)))
        assert k1 > k0 + 0.1


class TestMoments:
    @settings(max_examples=10, deadline=None)
    @given(seed=st.integers(0, 2**31 - 1))
    def test_band_moments_vs_monte_carlo(self, seed):
        rng = np.random.default_rng(seed)
        fm, fv = rng.normal(3.0, 0.5), rng.uniform(0.05, 0.5)
        cm = rng.normal(0.3, 0.2, 4)
        cv = rng.uniform(0.02, 0.2, 4)
        m1, m2 = ref.band_loglum_moments(
            jnp.float32(fm), jnp.float32(fv), jnp.asarray(cm, jnp.float32),
            jnp.asarray(cv, jnp.float32),
        )
        n = 200_000
        logr = rng.normal(fm, np.sqrt(fv), n)
        c = rng.normal(cm, np.sqrt(cv), (n, 4))
        a = np.asarray(C.COLOR_COEF)
        for b in range(C.N_BANDS):
            lb = np.exp(logr + c @ a[b])
            np.testing.assert_allclose(m1[b], lb.mean(), rtol=0.05)
            np.testing.assert_allclose(m2[b], (lb**2).mean(), rtol=0.25)

    def test_ref_band_ignores_colors(self):
        """In the reference band log l = log r exactly."""
        m1a, _ = ref.band_loglum_moments(
            jnp.float32(2.0), jnp.float32(0.1),
            jnp.zeros(4), jnp.full((4,), 0.3),
        )
        m1b, _ = ref.band_loglum_moments(
            jnp.float32(2.0), jnp.float32(0.1),
            jnp.ones(4) * 5.0, jnp.full((4,), 0.9),
        )
        np.testing.assert_allclose(m1a[C.REF_BAND], m1b[C.REF_BAND], rtol=1e-6)


class TestBuildInputs:
    def test_star_mixture_normalized(self):
        """Star components integrate to ~1 (PSF weights sum to 1)."""
        t = jnp.asarray(random_theta(RNG))
        psf, gain = jnp.asarray(default_psf()), jnp.ones(C.N_BANDS)
        comps_s, comps_g, _ = model.build_inputs(t, psf, gain)
        for b in range(C.N_BANDS):
            for comps in (comps_s[b], comps_g[b]):
                img = ref.mog_eval(comps, h=128, w=128)
                # recenter: patch grid is 32x32; rebuild with big patch
            # analytic integral: sum of w (normalization folded in w_eff)
            det_terms = []
        # analytic check instead: sum w_eff * 2*pi/sqrt(det(precision))
        for b in range(C.N_BANDS):
            for comps in (comps_s[b], comps_g[b]):
                p = np.asarray(comps)
                det = p[:, 3] * p[:, 5] - p[:, 4] ** 2
                integral = np.sum(p[:, 0] * 2 * np.pi / np.sqrt(det))
                np.testing.assert_allclose(integral, 1.0, rtol=1e-4)

    def test_gamma_split(self):
        """scal star/gal entries scale with (1-γ) and γ."""
        t = random_theta(RNG)
        psf, gain = jnp.asarray(default_psf()), jnp.ones(C.N_BANDS)
        t[C.I_A] = 10.0  # certainly a galaxy
        _, _, scal = model.build_inputs(jnp.asarray(t), psf, gain)
        assert float(jnp.abs(scal[:, 0]).max()) < 1e-3 * float(
            jnp.abs(scal[:, 1]).max()
        )

    def test_scale_grows_galaxy(self):
        t = random_theta(RNG)
        t[C.I_A] = 10.0
        psf, gain = jnp.asarray(default_psf()), jnp.ones(C.N_BANDS)
        imgs = []
        for logs in (0.0, 1.5):
            t[C.I_SHAPE + 3] = logs
            _, comps_g, _ = model.build_inputs(jnp.asarray(t), psf, gain)
            imgs.append(np.asarray(ref.mog_eval(comps_g[2])))
        # larger scale => lower peak (same total flux)
        assert imgs[1].max() < imgs[0].max()


class TestDerivatives:
    """Autodiff vs (f64) finite differences of the analytic objective."""

    @pytest.fixture(autouse=True)
    def x64(self):
        jax.config.update("jax_enable_x64", True)
        yield
        jax.config.update("jax_enable_x64", False)

    def test_like_grad_finite_diff(self):
        rng = np.random.default_rng(3)
        theta, pixels, bg, mask, psf, gain = synthetic_patch(rng)
        args = [jnp.asarray(a, jnp.float64) for a in (pixels, bg, mask, psf, gain)]
        t = jnp.asarray(theta, jnp.float64)
        f = lambda th: model.elbo_like(th, *args)
        g = jax.grad(f)(t)
        eps = 1e-5
        for i in range(0, C.DIM, 3):
            e = jnp.zeros(C.DIM, jnp.float64).at[i].set(eps)
            fd = (float(f(t + e)) - float(f(t - e))) / (2 * eps)
            np.testing.assert_allclose(float(g[i]), fd, rtol=2e-4, atol=1e-4)

    def test_kl_grad_finite_diff(self):
        prior = jnp.asarray(default_prior(), jnp.float64)
        t = jnp.asarray(random_theta(RNG), jnp.float64)
        f = lambda th: model.elbo_kl(th, prior)
        g = jax.grad(f)(t)
        eps = 1e-6
        for i in range(C.DIM):
            e = jnp.zeros(C.DIM, jnp.float64).at[i].set(eps)
            fd = (float(f(t + e)) - float(f(t - e))) / (2 * eps)
            np.testing.assert_allclose(float(g[i]), fd, rtol=5e-4, atol=1e-6)

    def test_hessian_symmetric(self):
        rng = np.random.default_rng(5)
        theta, pixels, bg, mask, psf, gain = synthetic_patch(rng)
        args = [jnp.asarray(a, jnp.float64) for a in (pixels, bg, mask, psf, gain)]
        h = jax.hessian(model.elbo_like)(jnp.asarray(theta, jnp.float64), *args)
        np.testing.assert_allclose(h, h.T, atol=1e-8)

    def test_kl_hessian_pd_at_prior(self):
        """At the prior-matching point the KL Hessian is PSD (+ridge > 0)."""
        prior = default_prior()
        t = jnp.asarray(prior_matching_theta(prior), jnp.float64)
        h = jax.hessian(model.elbo_kl)(t, jnp.asarray(prior, jnp.float64))
        w = np.linalg.eigvalsh(np.asarray(h))
        assert w.min() > 0


class TestEndToEndFit:
    def test_true_theta_beats_perturbed(self):
        """ELBO at the generating θ exceeds ELBO at a perturbed θ (data fit)."""
        rng = np.random.default_rng(11)
        theta, pixels, bg, mask, psf, gain = synthetic_patch(rng)
        prior = jnp.asarray(default_prior())
        args = map(jnp.asarray, (pixels, bg, mask, psf, gain))
        pixels, bg, mask, psf, gain = args
        e_true = float(model.elbo(jnp.asarray(theta), pixels, bg, mask, psf, gain, prior))
        bad = theta.copy()
        bad[C.I_LOC] += 4.0  # 4-pixel location error
        e_bad = float(model.elbo(jnp.asarray(bad), pixels, bg, mask, psf, gain, prior))
        assert e_true > e_bad
