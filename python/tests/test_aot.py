"""AOT pipeline validation: lowering, manifest consistency, HLO sanity."""

import json
import os

import numpy as np
import jax
import jax.numpy as jnp

from compile import aot, constants as C, model


def test_artifact_defs_cover_required():
    defs = aot.artifact_defs()
    for name in (C.ART_LIKE_AD, C.ART_LIKE_PALLAS, C.ART_KL, C.ART_RENDER):
        assert name in defs


def test_lower_and_manifest(tmp_path):
    manifest = aot.lower_all(str(tmp_path), verbose=False)
    # every artifact file exists, is non-trivial HLO text
    for name, ent in manifest["artifacts"].items():
        p = tmp_path / ent["file"]
        assert p.exists(), name
        text = p.read_text()
        assert "HloModule" in text, name
        assert len(text) > 1000, name
    # manifest constants mirror constants.py
    cs = manifest["constants"]
    assert cs["dim"] == C.DIM
    assert cs["patch"] == C.PATCH
    assert cs["n_bands"] == C.N_BANDS
    assert cs["k_gal"] == C.K_GAL
    # round-trips through json
    js = json.dumps(manifest)
    assert json.loads(js)["constants"]["dim"] == C.DIM


def test_signatures_execute():
    """Every artifact function runs at its declared signature and produces
    the declared output shapes (what Rust will rely on)."""
    rng = np.random.default_rng(0)
    for name, (fn, args, outs) in aot.artifact_defs().items():
        inputs = []
        for argname, shape in args:
            if argname == "pixels":
                a = rng.poisson(60.0, shape).astype(np.float32)
            elif argname in ("bg",):
                a = np.full(shape, 60.0, np.float32)
            elif argname == "mask":
                a = np.ones(shape, np.float32)
            elif argname == "gain":
                a = np.ones(shape, np.float32)
            elif argname == "psf":
                from conftest import default_psf

                a = default_psf()
            elif argname == "prior":
                from conftest import default_prior

                a = default_prior()
            elif argname == "theta":
                from conftest import random_theta

                a = random_theta(rng)
            elif argname == "comps":
                a = np.zeros(shape, np.float32)
                a[:, 0] = 0.1
                a[:, 1] = a[:, 2] = C.PATCH / 2
                a[:, 3] = a[:, 5] = 1.0
            else:
                a = rng.normal(0, 1, shape).astype(np.float32)
            inputs.append(jnp.asarray(a))
        result = fn(*inputs)
        if not isinstance(result, tuple):
            result = (result,)
        assert len(result) == len(outs), name
        for r, (oname, oshape) in zip(result, outs):
            assert tuple(r.shape) == tuple(oshape), (name, oname)
            assert np.all(np.isfinite(np.asarray(r))), (name, oname)


def test_hlo_deterministic():
    """Lowering is deterministic: same constants -> same HLO text."""
    defs = aot.artifact_defs()
    fn, args, _ = defs[C.ART_KL]
    specs = [jax.ShapeDtypeStruct(tuple(s), jnp.float32) for _, s in args]
    t1 = aot.to_hlo_text(jax.jit(fn).lower(*specs))
    t2 = aot.to_hlo_text(jax.jit(fn).lower(*specs))
    assert t1 == t2


def test_no_elided_constants_in_hlo():
    """Regression guard for the nastiest bug in this project: by default
    as_hlo_text() elides constants >= ~10 elements as "{...}", which the
    xla_extension 0.5.1 text parser silently reads back as ZEROS (our
    COLOR_COEF vanished and the model went color-blind). Lowering must
    always print large constants."""
    defs = aot.artifact_defs()
    fn, args, _ = defs[C.ART_KL]
    specs = [jax.ShapeDtypeStruct(tuple(s), jnp.float64) for _, s in args]
    text = aot.to_hlo_text(jax.jit(fn).lower(*specs))
    assert "{...}" not in text

    fn, args, _ = defs[C.ART_LIKE_AD]
    specs = [jax.ShapeDtypeStruct(tuple(s), jnp.float64) for _, s in args]
    text = aot.to_hlo_text(jax.jit(fn).lower(*specs))
    assert "{...}" not in text
    # the COLOR_COEF constant itself must appear with its -1 entries
    assert "f64[5,4]" in text
