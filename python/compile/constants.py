"""Model constants shared by the L1 kernels, L2 model, AOT lowering, and
(via artifacts/manifest.json) the Rust coordinator.

The single source of truth for shapes and the variational-parameter layout.
Mirrored on the Rust side by `rust/src/model/layout.rs`; `aot.py` emits
`manifest.json` from these values and the Rust side asserts agreement at
startup, so the two can never drift silently.
"""

# ---------------------------------------------------------------------------
# Image geometry
# ---------------------------------------------------------------------------

#: Number of filter bands (SDSS ugriz).
N_BANDS = 5

#: Index of the reference band (SDSS r-band) for brightness.
REF_BAND = 2

#: Patch height/width in pixels. Every light source is optimized against
#: fixed-size patches cut from each field that contains it.
PATCH = 32

# ---------------------------------------------------------------------------
# PSF / galaxy mixture structure
# ---------------------------------------------------------------------------

#: Gaussian components in the per-band PSF model.
K_PSF = 2

#: Parameters per PSF component: (weight, dx, dy, cxx, cxy, cyy) where c* is
#: the covariance of the component and (dx, dy) its offset from the source
#: center (models PSF asymmetry).
PSF_PARAMS = 6

#: Gaussian components per galaxy radial profile (exponential / de Vauc.).
K_PROFILE = 4

#: Effective star components per band: the PSF itself.
K_STAR = K_PSF

#: Effective galaxy components per band: (exp 4 + deV 4) profile components,
#: each convolved with each PSF component.
K_GAL = 2 * K_PROFILE * K_PSF

#: Parameters per *effective* (post-convolution) Gaussian component:
#: (w, mx, my, p00, p01, p11) — weight with normalization folded in, mean,
#: and precision-matrix entries.
COMP_PARAMS = 6

# Mixture-of-Gaussians approximations of the two canonical galaxy radial
# profiles, as (amplitude, variance) pairs in units of the half-light
# radius squared. Four components each (compact table in the spirit of
# Hogg & Lang 2013). Amplitudes sum to 1.
PROFILE_EXP_AMP = (0.30, 0.40, 0.25, 0.05)
PROFILE_EXP_VAR = (0.12, 0.50, 1.30, 3.00)
PROFILE_DEV_AMP = (0.35, 0.35, 0.20, 0.10)
PROFILE_DEV_VAR = (0.03, 0.25, 1.20, 6.00)

# ---------------------------------------------------------------------------
# Variational parameter vector θ (per light source)
# ---------------------------------------------------------------------------
# All entries are unconstrained reals; constrained quantities go through
# sigmoid / exp transforms inside the model. The paper uses 32 entries per
# source; our reduced color/shape layout yields 27 with identical structure
# (Bernoulli type, lognormal flux, MVN colors, non-random location+shape).

#: logit of q(a_s = galaxy)
I_A = 0
#: location offset (du, dv) in pixels from the patch center
I_LOC = 1
#: star flux: (mean, log-variance) of q(log r | star)
I_FLUX_STAR = 3
#: galaxy flux: (mean, log-variance) of q(log r | galaxy)
I_FLUX_GAL = 5
#: star color means, 4 entries
I_COLOR_MEAN_STAR = 7
#: galaxy color means, 4 entries
I_COLOR_MEAN_GAL = 11
#: star color log-variances, 4 entries
I_COLOR_VAR_STAR = 15
#: galaxy color log-variances, 4 entries
I_COLOR_VAR_GAL = 19
#: galaxy shape: (logit deV-mixture, logit axis-ratio, angle, log scale)
I_SHAPE = 23

#: total θ dimension
DIM = 27

#: number of colors = N_BANDS - 1
N_COLORS = 4

# ---------------------------------------------------------------------------
# Prior vector layout (21 entries), passed to the KL artifact
# ---------------------------------------------------------------------------
P_A = 0                # prior probability of galaxy
P_FLUX_STAR = 1        # (mean, variance) of log r | star
P_FLUX_GAL = 3         # (mean, variance) of log r | galaxy
P_COLOR_MEAN_STAR = 5  # 4 entries
P_COLOR_MEAN_GAL = 9   # 4 entries
P_COLOR_VAR_STAR = 13  # 4 entries
P_COLOR_VAR_GAL = 17   # 4 entries
PRIOR_DIM = 21

#: ridge regularizer applied (in the KL term) to the location and angle
#: entries, keeping the per-source Hessian positive-definite even when q(a)
#: collapses to "star" and the data carries no shape information.
RIDGE = 1e-4

# Gaussian (negative-log-)priors on the point-estimated galaxy shape
# parameters, weighted by q(a = galaxy). Without these the model is
# degenerate: a galaxy shrunk to zero scale is indistinguishable from a
# star, so q(a) drifts arbitrarily. (Real Celeste likewise places priors
# on galaxy shape.) Tuples are (mean, variance) in the unconstrained
# parameterization.
SHAPE_PRIOR_PDEV = (0.0, 4.0)     # logit of the deV mixture weight
SHAPE_PRIOR_AXIS = (0.0, 4.0)     # logit of the axis ratio
SHAPE_PRIOR_SCALE = (0.5, 0.25)    # log of the half-light radius (px)

# ---------------------------------------------------------------------------
# Band flux mapping: log l_b = log r + COLOR_COEF[b] . c,
# with colors c_i = log(l_{i+1} / l_i) and reference band REF_BAND.
# ---------------------------------------------------------------------------
COLOR_COEF = (
    (-1.0, -1.0, 0.0, 0.0),
    (0.0, -1.0, 0.0, 0.0),
    (0.0, 0.0, 0.0, 0.0),
    (0.0, 0.0, 1.0, 0.0),
    (0.0, 0.0, 1.0, 1.0),
)

#: Artifact names (basenames under artifacts/).
ART_LIKE_AD = "like_ad"
ART_LIKE_PALLAS = "like_pallas"
ART_KL = "kl"
ART_RENDER = "render_pallas"
MANIFEST = "manifest.json"
