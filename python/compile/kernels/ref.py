"""Pure-jnp reference implementations (the correctness oracle).

Everything here is differentiable jnp code: the L2 model builds its
autodiff artifact on these functions, and the Pallas kernels in
`mog_render.py` are validated against them by pytest/hypothesis.
"""

import jax.numpy as jnp

from .. import constants as C


def pixel_grid(h, w, dtype=jnp.float32):
    """Pixel-center coordinates of an h x w patch: two (h, w) arrays.

    Pixel (i, j) has center (j + 0.5, i + 0.5) in (x, y) = (col, row)
    convention, matching the Rust renderer (`imaging::render`).
    """
    ys = (jnp.arange(h, dtype=dtype) + 0.5)[:, None] * jnp.ones((1, w), dtype)
    xs = (jnp.arange(w, dtype=dtype) + 0.5)[None, :] * jnp.ones((h, 1), dtype)
    return xs, ys


def mog_eval(comps, h=C.PATCH, w=C.PATCH):
    """Evaluate a Gaussian mixture on a pixel grid.

    comps: (K, 6) rows (w_eff, mx, my, p00, p01, p11) — weight with the
    1/(2*pi*sqrt(det V)) normalization already folded in, mean, precision.
    Returns (h, w) mixture density (flux per unit pixel area).
    """
    xs, ys = pixel_grid(h, w, comps.dtype)
    dx = xs[None] - comps[:, 1][:, None, None]
    dy = ys[None] - comps[:, 2][:, None, None]
    q = (
        comps[:, 3][:, None, None] * dx * dx
        + 2.0 * comps[:, 4][:, None, None] * dx * dy
        + comps[:, 5][:, None, None] * dy * dy
    )
    return jnp.sum(comps[:, 0][:, None, None] * jnp.exp(-0.5 * q), axis=0)


def band_loglum_moments(flux_mean, flux_var, color_mean, color_var):
    """Per-band first/second moments of the (lognormal) band luminosity.

    log l_b = log r + COLOR_COEF[b] . c  is normal with
      m_b = flux_mean + A_b . color_mean
      v_b = flux_var  + |A_b| . color_var     (A entries are in {-1, 0, 1})
    Returns (m1, m2): E[l_b] and E[l_b^2], each shape (N_BANDS,).
    """
    a = jnp.asarray(C.COLOR_COEF, dtype=flux_mean.dtype)
    m = flux_mean + a @ color_mean
    v = flux_var + jnp.abs(a) @ color_var
    m1 = jnp.exp(m + 0.5 * v)
    m2 = jnp.exp(2.0 * m + 2.0 * v)
    return m1, m2


def expected_pixel_terms(gs, gg, bg, scal):
    """Per-pixel E[F], E[log F] under the variational distribution.

    gs, gg: (h, w) star/galaxy spatial mixtures for one band.
    bg:     (h, w) background rate (sky + fixed neighbors), > 0.
    scal:   (6,) = (gamma_star*m1s, gamma_gal*m1g,
                    gamma_star*m2s, gamma_gal*m2g, unused, unused)
            premultiplied moment scalars for this band.
    Uses the second-order delta approximation
      E[log F] ~= log E[F] - Var[F] / (2 E[F]^2).
    Returns (ef, elogf).
    """
    u = scal[0] * gs + scal[1] * gg
    ef = bg + u
    ex2 = scal[2] * gs * gs + scal[3] * gg * gg
    varf = jnp.maximum(ex2 - u * u, 0.0)
    elogf = jnp.log(ef) - varf / (2.0 * ef * ef)
    return ef, elogf


def poisson_elbo_band(pixels, bg, mask, gs, gg, scal):
    """Masked Poisson expected log-likelihood for one band (constants
    -log x! dropped; they do not depend on the parameters)."""
    ef, elogf = expected_pixel_terms(gs, gg, bg, scal)
    return jnp.sum(mask * (pixels * elogf - ef))
