"""L1: Pallas kernels for the Celeste pixel hot spot.

The inner loop of Celeste evaluates a Gaussian-mixture rate image and the
expected Poisson log-likelihood (plus its gradient) over a pixel patch.
Following the paper, the gradient here is *manually derived* — autodiff
cannot differentiate through `pallas_call`, and the paper itself computes
gradients by hand for performance (§III-B).

TPU mapping (DESIGN.md §5): the component table (K x 6) stays resident in
VMEM across the whole grid while BlockSpec streams row-blocks of the patch
HBM->VMEM; per-pixel work is VPU element-wise + small reductions. Kernels
are lowered with interpret=True (CPU PJRT cannot execute Mosaic calls).

Validated against `ref.py` by `python/tests/test_kernels.py`.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .. import constants as C

#: rows per grid step (VMEM tile height)
TILE_H = 8


def _tile_coords(tile_h, w, dtype):
    """Pixel-center coordinates of the current row tile."""
    row0 = pl.program_id(0) * tile_h
    ys = jax.lax.broadcasted_iota(dtype, (tile_h, w), 0) + (row0 + 0.5)
    xs = jax.lax.broadcasted_iota(dtype, (tile_h, w), 1) + 0.5
    return xs, ys


def _mixture(comps, xs, ys):
    """Evaluate every component on the tile.

    comps: (K, 6); returns (es (K,th,w) per-comp exp term, g (th,w) sum,
    dx, dy (K,th,w) offsets) — the pieces the manual gradient reuses.
    """
    w = comps[:, 0][:, None, None]
    dx = xs[None] - comps[:, 1][:, None, None]
    dy = ys[None] - comps[:, 2][:, None, None]
    q = (
        comps[:, 3][:, None, None] * dx * dx
        + 2.0 * comps[:, 4][:, None, None] * dx * dy
        + comps[:, 5][:, None, None] * dy * dy
    )
    es = jnp.exp(-0.5 * q)
    g = jnp.sum(w * es, axis=0)
    return es, g, dx, dy


# ---------------------------------------------------------------------------
# Kernel 1: standalone MoG render (rate image)
# ---------------------------------------------------------------------------

def _render_kernel(comps_ref, out_ref):
    xs, ys = _tile_coords(out_ref.shape[0], out_ref.shape[1], out_ref.dtype)
    _, g, _, _ = _mixture(comps_ref[...], xs, ys)
    out_ref[...] = g


def render(comps, h=C.PATCH, w=C.PATCH):
    """Render a (K, 6) effective-component mixture to an (h, w) image."""
    k = comps.shape[0]
    return pl.pallas_call(
        _render_kernel,
        grid=(h // TILE_H,),
        in_specs=[pl.BlockSpec((k, C.COMP_PARAMS), lambda i: (0, 0))],
        out_specs=pl.BlockSpec((TILE_H, w), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((h, w), comps.dtype),
        interpret=True,
    )(comps)


# ---------------------------------------------------------------------------
# Kernel 2: fused expected-Poisson log-likelihood, value only
# ---------------------------------------------------------------------------

def _pixel_terms(gs, gg, bg, scal):
    """ef, u, varf, elogf for a tile (mirrors ref.expected_pixel_terms)."""
    u = scal[0] * gs + scal[1] * gg
    ef = bg + u
    ex2 = scal[2] * gs * gs + scal[3] * gg * gg
    varf = jnp.maximum(ex2 - u * u, 0.0)
    elogf = jnp.log(ef) - varf / (2.0 * ef * ef)
    return ef, u, varf, elogf


def _like_kernel(pix_ref, bg_ref, mask_ref, cs_ref, cg_ref, scal_ref, ll_ref):
    xs, ys = _tile_coords(pix_ref.shape[0], pix_ref.shape[1], pix_ref.dtype)
    _, gs, _, _ = _mixture(cs_ref[...], xs, ys)
    _, gg, _, _ = _mixture(cg_ref[...], xs, ys)
    scal = scal_ref[0, :]
    ef, _, _, elogf = _pixel_terms(gs, gg, bg_ref[...], scal)
    part = jnp.sum(mask_ref[...] * (pix_ref[...] * elogf - ef))

    @pl.when(pl.program_id(0) == 0)
    def _init():
        ll_ref[...] = jnp.zeros_like(ll_ref)

    ll_ref[0, 0] += part


def like_band(pixels, bg, mask, comps_s, comps_g, scal):
    """Masked expected Poisson log-likelihood of one band (value only)."""
    h, w = pixels.shape
    ks, kg = comps_s.shape[0], comps_g.shape[0]
    full = lambda i: (0, 0)
    tile = lambda i: (i, 0)
    out = pl.pallas_call(
        _like_kernel,
        grid=(h // TILE_H,),
        in_specs=[
            pl.BlockSpec((TILE_H, w), tile),
            pl.BlockSpec((TILE_H, w), tile),
            pl.BlockSpec((TILE_H, w), tile),
            pl.BlockSpec((ks, C.COMP_PARAMS), full),
            pl.BlockSpec((kg, C.COMP_PARAMS), full),
            pl.BlockSpec((1, 6), full),
        ],
        out_specs=pl.BlockSpec((1, 1), full),
        out_shape=jax.ShapeDtypeStruct((1, 1), pixels.dtype),
        interpret=True,
    )(pixels, bg, mask, comps_s, comps_g, scal.reshape(1, 6))
    return out[0, 0]


# ---------------------------------------------------------------------------
# Kernel 3: fused likelihood + manual gradient
# ---------------------------------------------------------------------------

def _comp_cotangents(dg, comps, es, dx, dy):
    """Chain a per-pixel cotangent dg = dll/dg(m) to the component params.

    Returns (K, 6) cotangents for (w, mx, my, p00, p01, p11).
    For g = sum_k w_k exp(-q_k/2), q_k = p00 dx^2 + 2 p01 dx dy + p11 dy^2:
      dg/dw_k  = e_k
      dg/dmx_k = w_k e_k (p00 dx + p01 dy)      (d dx/dmx = -1 cancels -1/2*2)
      dg/dmy_k = w_k e_k (p01 dx + p11 dy)
      dg/dp00  = -w_k e_k dx^2 / 2
      dg/dp01  = -w_k e_k dx dy
      dg/dp11  = -w_k e_k dy^2 / 2
    """
    w = comps[:, 0][:, None, None]
    p00 = comps[:, 3][:, None, None]
    p01 = comps[:, 4][:, None, None]
    p11 = comps[:, 5][:, None, None]
    dge = dg[None] * es
    pref = dge * w
    dw = jnp.sum(dge, axis=(1, 2))
    dmx = jnp.sum(pref * (p00 * dx + p01 * dy), axis=(1, 2))
    dmy = jnp.sum(pref * (p01 * dx + p11 * dy), axis=(1, 2))
    dp00 = jnp.sum(pref * (-0.5 * dx * dx), axis=(1, 2))
    dp01 = jnp.sum(pref * (-dx * dy), axis=(1, 2))
    dp11 = jnp.sum(pref * (-0.5 * dy * dy), axis=(1, 2))
    return jnp.stack([dw, dmx, dmy, dp00, dp01, dp11], axis=-1)


def _like_grad_kernel(
    pix_ref, bg_ref, mask_ref, cs_ref, cg_ref, scal_ref,
    ll_ref, dcs_ref, dcg_ref, dscal_ref,
):
    xs, ys = _tile_coords(pix_ref.shape[0], pix_ref.shape[1], pix_ref.dtype)
    cs, cg = cs_ref[...], cg_ref[...]
    es_s, gs, dx_s, dy_s = _mixture(cs, xs, ys)
    es_g, gg, dx_g, dy_g = _mixture(cg, xs, ys)
    scal = scal_ref[0, :]
    pix, bg, mask = pix_ref[...], bg_ref[...], mask_ref[...]

    ef, u, varf, elogf = _pixel_terms(gs, gg, bg, scal)
    ll = jnp.sum(mask * (pix * elogf - ef))

    # ll = sum mask*(x*elogf - ef); elogf = log ef - varf/(2 ef^2).
    # For a partial dxi: dll = sum c1 * def + c2 * dvarf, with
    #   c1 = mask*x*(1/ef + varf/ef^3) - mask,  c2 = -mask*x/(2 ef^2).
    a = mask * pix
    inv_ef = 1.0 / ef
    c1 = a * (inv_ef + varf * inv_ef * inv_ef * inv_ef) - mask
    c2 = -0.5 * a * inv_ef * inv_ef
    # ef = bg + s0 gs + s1 gg; varf = s2 gs^2 + s3 gg^2 - u^2.
    dgs = c1 * scal[0] + c2 * (2.0 * scal[2] * gs - 2.0 * u * scal[0])
    dgg = c1 * scal[1] + c2 * (2.0 * scal[3] * gg - 2.0 * u * scal[1])
    c12u = c1 - 2.0 * u * c2
    ds0 = jnp.sum(gs * c12u)
    ds1 = jnp.sum(gg * c12u)
    ds2 = jnp.sum(c2 * gs * gs)
    ds3 = jnp.sum(c2 * gg * gg)
    zero = jnp.zeros_like(ds0)
    dscal = jnp.stack([ds0, ds1, ds2, ds3, zero, zero]).reshape(1, 6)

    dcs = _comp_cotangents(dgs, cs, es_s, dx_s, dy_s)
    dcg = _comp_cotangents(dgg, cg, es_g, dx_g, dy_g)

    @pl.when(pl.program_id(0) == 0)
    def _init():
        ll_ref[...] = jnp.zeros_like(ll_ref)
        dcs_ref[...] = jnp.zeros_like(dcs_ref)
        dcg_ref[...] = jnp.zeros_like(dcg_ref)
        dscal_ref[...] = jnp.zeros_like(dscal_ref)

    ll_ref[0, 0] += ll
    dcs_ref[...] += dcs
    dcg_ref[...] += dcg
    dscal_ref[...] += dscal


def like_grad_band(pixels, bg, mask, comps_s, comps_g, scal):
    """One band's likelihood value plus manual cotangents w.r.t. the
    effective components and moment scalars.

    Returns (ll, dcomps_s (Ks,6), dcomps_g (Kg,6), dscal (6,)).
    """
    h, w = pixels.shape
    ks, kg = comps_s.shape[0], comps_g.shape[0]
    dt = pixels.dtype
    full = lambda i: (0, 0)
    tile = lambda i: (i, 0)
    ll, dcs, dcg, dscal = pl.pallas_call(
        _like_grad_kernel,
        grid=(h // TILE_H,),
        in_specs=[
            pl.BlockSpec((TILE_H, w), tile),
            pl.BlockSpec((TILE_H, w), tile),
            pl.BlockSpec((TILE_H, w), tile),
            pl.BlockSpec((ks, C.COMP_PARAMS), full),
            pl.BlockSpec((kg, C.COMP_PARAMS), full),
            pl.BlockSpec((1, 6), full),
        ],
        out_specs=[
            pl.BlockSpec((1, 1), full),
            pl.BlockSpec((ks, C.COMP_PARAMS), full),
            pl.BlockSpec((kg, C.COMP_PARAMS), full),
            pl.BlockSpec((1, 6), full),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((1, 1), dt),
            jax.ShapeDtypeStruct((ks, C.COMP_PARAMS), dt),
            jax.ShapeDtypeStruct((kg, C.COMP_PARAMS), dt),
            jax.ShapeDtypeStruct((1, 6), dt),
        ],
        interpret=True,
    )(pixels, bg, mask, comps_s, comps_g, scal.reshape(1, 6))
    return ll[0, 0], dcs, dcg, dscal[0, :]


# ---------------------------------------------------------------------------
# Full manual value+gradient over theta (the like_pallas artifact body)
# ---------------------------------------------------------------------------

def like_pallas_vg(theta, pixels, bg, mask, psf, gain):
    """(value, grad) of elbo_like with the Pallas manual-gradient path.

    The theta -> (components, scalars) map is tiny, differentiable jnp; its
    VJP chains the kernel's manual cotangents back to theta. The per-pixel
    work — the actual hot spot — never touches autodiff.
    """
    from .. import model  # deferred: model imports ref, not us

    prim, vjp_fn = jax.vjp(
        lambda th: model.build_inputs(th, psf, gain), theta
    )
    comps_s, comps_g, scal = prim

    ll = jnp.asarray(0.0, theta.dtype)
    dcs, dcg, dscal = [], [], []
    for b in range(C.N_BANDS):
        llb, dcs_b, dcg_b, dscal_b = like_grad_band(
            pixels[b], bg[b], mask[b], comps_s[b], comps_g[b], scal[b]
        )
        ll = ll + llb
        dcs.append(dcs_b)
        dcg.append(dcg_b)
        dscal.append(dscal_b)

    (grad,) = vjp_fn((jnp.stack(dcs), jnp.stack(dcg), jnp.stack(dscal)))
    return ll, grad
