"""AOT lowering: jit → StableHLO → XLA HLO *text* + manifest.json.

Run once by `make artifacts`; Python never appears on the inference path.

HLO text (not `.serialize()`) is the interchange format: jax >= 0.5 emits
HloModuleProto with 64-bit instruction ids which xla_extension 0.5.1 (the
version the rust `xla` 0.1.6 crate binds) rejects; the text parser
reassigns ids and round-trips cleanly (see /opt/xla-example/README.md).

The manifest records every artifact's signature plus the model constants,
and the Rust side (`runtime::manifest`) validates both at startup.
"""

import argparse
import json
import os

import jax

# The paper's Julia implementation computes in double precision; f32
# artifacts put ~4-nat ELBO differences (star-vs-galaxy at 1e6 scale)
# below the rounding floor, so we lower everything in f64.
jax.config.update("jax_enable_x64", True)
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import constants as C
from . import model
from .kernels import mog_render


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    # print_large_constants=True is ESSENTIAL: the default elides arrays
    # >= ~10 elements as "{...}", which xla_extension 0.5.1's text parser
    # silently reads back as ZEROS (it cost us a day: the COLOR_COEF
    # constant vanished and the model went color-blind).
    return comp.as_hlo_text(print_large_constants=True)


def _spec(*shape):
    return jax.ShapeDtypeStruct(tuple(shape), jnp.float64)


def artifact_defs():
    """name -> (fn, [(arg_name, shape)], [(out_name, shape)])"""
    B, P, D = C.N_BANDS, C.PATCH, C.DIM
    patch = (B, P, P)
    like_args = [
        ("theta", (D,)),
        ("pixels", patch),
        ("bg", patch),
        ("mask", patch),
        ("psf", (B, C.K_PSF, C.PSF_PARAMS)),
        ("gain", (B,)),
    ]
    vgh = [("value", ()), ("grad", (D,)), ("hess", (D, D))]
    return {
        C.ART_LIKE_AD: (model.like_vgh, like_args, vgh),
        C.ART_LIKE_PALLAS: (
            mog_render.like_pallas_vg,
            like_args,
            [("value", ()), ("grad", (D,))],
        ),
        C.ART_KL: (
            model.kl_vgh,
            [("theta", (D,)), ("prior", (C.PRIOR_DIM,))],
            vgh,
        ),
        C.ART_RENDER: (
            mog_render.render,
            [("comps", (C.K_GAL, C.COMP_PARAMS))],
            [("image", (P, P))],
        ),
    }


def lower_all(out_dir: str, verbose: bool = True) -> dict:
    os.makedirs(out_dir, exist_ok=True)
    manifest = {
        "format": "hlo-text",
        "constants": {
            "dim": C.DIM,
            "prior_dim": C.PRIOR_DIM,
            "n_bands": C.N_BANDS,
            "ref_band": C.REF_BAND,
            "patch": C.PATCH,
            "k_psf": C.K_PSF,
            "psf_params": C.PSF_PARAMS,
            "k_star": C.K_STAR,
            "k_gal": C.K_GAL,
            "comp_params": C.COMP_PARAMS,
            "ridge": C.RIDGE,
            "shape_prior_pdev": list(C.SHAPE_PRIOR_PDEV),
            "shape_prior_axis": list(C.SHAPE_PRIOR_AXIS),
            "shape_prior_scale": list(C.SHAPE_PRIOR_SCALE),
            "i_a": C.I_A,
            "i_loc": C.I_LOC,
            "i_flux_star": C.I_FLUX_STAR,
            "i_flux_gal": C.I_FLUX_GAL,
            "i_color_mean_star": C.I_COLOR_MEAN_STAR,
            "i_color_mean_gal": C.I_COLOR_MEAN_GAL,
            "i_color_var_star": C.I_COLOR_VAR_STAR,
            "i_color_var_gal": C.I_COLOR_VAR_GAL,
            "i_shape": C.I_SHAPE,
            "profile_exp_amp": list(C.PROFILE_EXP_AMP),
            "profile_exp_var": list(C.PROFILE_EXP_VAR),
            "profile_dev_amp": list(C.PROFILE_DEV_AMP),
            "profile_dev_var": list(C.PROFILE_DEV_VAR),
            "color_coef": [list(r) for r in C.COLOR_COEF],
        },
        "artifacts": {},
    }
    for name, (fn, args, outs) in artifact_defs().items():
        specs = [_spec(*shape) for _, shape in args]
        lowered = jax.jit(fn).lower(*specs)
        text = to_hlo_text(lowered)
        fname = f"{name}.hlo.txt"
        with open(os.path.join(out_dir, fname), "w") as f:
            f.write(text)
        manifest["artifacts"][name] = {
            "file": fname,
            "inputs": [
                {"name": n, "shape": list(s), "dtype": "f64"} for n, s in args
            ],
            "outputs": [
                {"name": n, "shape": list(s), "dtype": "f64"} for n, s in outs
            ],
        }
        if verbose:
            print(f"lowered {name}: {len(text)} chars -> {fname}")
    with open(os.path.join(out_dir, C.MANIFEST), "w") as f:
        json.dump(manifest, f, indent=1)
    if verbose:
        print(f"wrote {C.MANIFEST} ({len(manifest['artifacts'])} artifacts)")
    return manifest


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default="../artifacts", help="output directory")
    ap.add_argument("-q", "--quiet", action="store_true")
    args = ap.parse_args()
    lower_all(args.out, verbose=not args.quiet)


if __name__ == "__main__":
    main()
