"""L2: the Celeste model — variational ELBO over the per-source parameters.

This module is pure, differentiable jnp (the Pallas fast path lives in
`kernels/mog_render.py` and is validated against this code). It is executed
only at build time: `aot.py` lowers the jitted value/grad/Hessian functions
to HLO text which the Rust coordinator loads through PJRT.

Model summary (paper §III-A):
  x_nmb ~ Poisson(F_nmb),
  F_nmb = bg_nmb + gain_b * l_b(r_s, c_s) * g_{a_s,b}(m; mu_s, phi_s),
with a_s ~ Bernoulli (star/galaxy), log r_s ~ Normal, colors c_s ~ Normal,
and g the PSF (star) or the PSF-convolved galaxy mixture (galaxy).

Variational family (paper §III-B): q(a) Bernoulli, q(log r | a) Normal,
q(c | a) diagonal Normal; location and shape are point-estimated. The
resulting ELBO = E_q[log p(x|z)] - KL(q || prior) is analytic given the
second-order delta approximation of E[log F] (see kernels/ref.py).
"""

import jax
import jax.numpy as jnp

from . import constants as C
from .kernels import ref


# ---------------------------------------------------------------------------
# Parameter transforms
# ---------------------------------------------------------------------------

def sigmoid(x):
    return 1.0 / (1.0 + jnp.exp(-x))


def unpack(theta):
    """Split the unconstrained theta vector into named constrained pieces."""
    return {
        "gamma_gal": sigmoid(theta[C.I_A]),
        "loc": theta[C.I_LOC : C.I_LOC + 2],
        "flux_star": (theta[C.I_FLUX_STAR], jnp.exp(theta[C.I_FLUX_STAR + 1])),
        "flux_gal": (theta[C.I_FLUX_GAL], jnp.exp(theta[C.I_FLUX_GAL + 1])),
        "color_mean_star": theta[C.I_COLOR_MEAN_STAR : C.I_COLOR_MEAN_STAR + 4],
        "color_mean_gal": theta[C.I_COLOR_MEAN_GAL : C.I_COLOR_MEAN_GAL + 4],
        "color_var_star": jnp.exp(theta[C.I_COLOR_VAR_STAR : C.I_COLOR_VAR_STAR + 4]),
        "color_var_gal": jnp.exp(theta[C.I_COLOR_VAR_GAL : C.I_COLOR_VAR_GAL + 4]),
        "p_dev": sigmoid(theta[C.I_SHAPE]),
        "axis_ratio": sigmoid(theta[C.I_SHAPE + 1]),
        "angle": theta[C.I_SHAPE + 2],
        "log_scale": theta[C.I_SHAPE + 3],
    }


# ---------------------------------------------------------------------------
# Effective Gaussian components
# ---------------------------------------------------------------------------

def _fold_norm(w, cxx, cxy, cyy):
    """Fold the bivariate-normal normalization into the weight and invert
    the covariance. Returns (w_eff, p00, p01, p11)."""
    det = cxx * cyy - cxy * cxy
    w_eff = w / (2.0 * jnp.pi * jnp.sqrt(det))
    p00 = cyy / det
    p01 = -cxy / det
    p11 = cxx / det
    return w_eff, p00, p01, p11


def star_comps_band(center, psf_b):
    """Star components for one band: the PSF translated to the source.

    psf_b: (K_PSF, 6) rows (w, dx, dy, cxx, cxy, cyy). Returns (K_STAR, 6)
    effective rows (w_eff, mx, my, p00, p01, p11).
    """
    w, dx, dy = psf_b[:, 0], psf_b[:, 1], psf_b[:, 2]
    cxx, cxy, cyy = psf_b[:, 3], psf_b[:, 4], psf_b[:, 5]
    w_eff, p00, p01, p11 = _fold_norm(w, cxx, cxy, cyy)
    return jnp.stack(
        [w_eff, center[0] + dx, center[1] + dy, p00, p01, p11], axis=-1
    )


def galaxy_base_cov(axis_ratio, angle, scale):
    """Unit-profile galaxy covariance: scale^2 R diag(1, q^2) R^T."""
    c, s = jnp.cos(angle), jnp.sin(angle)
    s1 = scale * scale
    s2 = s1 * axis_ratio * axis_ratio
    vxx = c * c * s1 + s * s * s2
    vyy = s * s * s1 + c * c * s2
    vxy = c * s * (s1 - s2)
    return vxx, vxy, vyy


def galaxy_comps_band(center, psf_b, p_dev, axis_ratio, angle, scale):
    """Galaxy components for one band: each (profile comp) x (PSF comp),
    convolved analytically. Returns (K_GAL, 6) effective rows."""
    amp_e = jnp.asarray(C.PROFILE_EXP_AMP, psf_b.dtype) * (1.0 - p_dev)
    amp_d = jnp.asarray(C.PROFILE_DEV_AMP, psf_b.dtype) * p_dev
    var = jnp.concatenate(
        [
            jnp.asarray(C.PROFILE_EXP_VAR, psf_b.dtype),
            jnp.asarray(C.PROFILE_DEV_VAR, psf_b.dtype),
        ]
    )
    amp = jnp.concatenate([amp_e, amp_d])  # (2*K_PROFILE,)
    vxx, vxy, vyy = galaxy_base_cov(axis_ratio, angle, scale)

    # Broadcast profile (i) against PSF (j): covariance var_i*V + C_j.
    w = amp[:, None] * psf_b[None, :, 0]
    cxx = var[:, None] * vxx + psf_b[None, :, 3]
    cxy = var[:, None] * vxy + psf_b[None, :, 4]
    cyy = var[:, None] * vyy + psf_b[None, :, 5]
    mx = center[0] + psf_b[None, :, 1] + jnp.zeros_like(w)
    my = center[1] + psf_b[None, :, 2] + jnp.zeros_like(w)
    w_eff, p00, p01, p11 = _fold_norm(w, cxx, cxy, cyy)
    comps = jnp.stack([w_eff, mx, my, p00, p01, p11], axis=-1)
    return comps.reshape(C.K_GAL, C.COMP_PARAMS)


def build_inputs(theta, psf, gain):
    """theta -> (comps_star (B,Ks,6), comps_gal (B,Kg,6), scal (B,6)).

    scal rows are the premultiplied per-band moment scalars consumed by
    `ref.expected_pixel_terms` / the Pallas kernel:
      (gam_s*gain*m1s, gam_g*gain*m1g, gam_s*gain^2*m2s, gam_g*gain^2*m2g, 0, 0).
    """
    p = unpack(theta)
    center = jnp.asarray([C.PATCH / 2.0, C.PATCH / 2.0], theta.dtype) + p["loc"]
    scale = jnp.exp(p["log_scale"])

    comps_s = jnp.stack(
        [star_comps_band(center, psf[b]) for b in range(C.N_BANDS)]
    )
    comps_g = jnp.stack(
        [
            galaxy_comps_band(
                center, psf[b], p["p_dev"], p["axis_ratio"], p["angle"], scale
            )
            for b in range(C.N_BANDS)
        ]
    )

    m1s, m2s = ref.band_loglum_moments(
        p["flux_star"][0], p["flux_star"][1],
        p["color_mean_star"], p["color_var_star"],
    )
    m1g, m2g = ref.band_loglum_moments(
        p["flux_gal"][0], p["flux_gal"][1],
        p["color_mean_gal"], p["color_var_gal"],
    )
    gam_g = p["gamma_gal"]
    gam_s = 1.0 - gam_g
    zero = jnp.zeros_like(m1s)
    scal = jnp.stack(
        [
            gam_s * gain * m1s,
            gam_g * gain * m1g,
            gam_s * gain * gain * m2s,
            gam_g * gain * gain * m2g,
            zero,
            zero,
        ],
        axis=-1,
    )
    return comps_s, comps_g, scal


# ---------------------------------------------------------------------------
# ELBO pieces
# ---------------------------------------------------------------------------

def elbo_like(theta, pixels, bg, mask, psf, gain):
    """Expected Poisson log-likelihood of one 5-band patch (one epoch).

    pixels/bg/mask: (N_BANDS, PATCH, PATCH); psf: (N_BANDS, K_PSF, 6);
    gain: (N_BANDS,). Additive across epochs — the Rust coordinator sums
    value/grad/Hessian over every field that contains the source.
    """
    comps_s, comps_g, scal = build_inputs(theta, psf, gain)
    total = jnp.asarray(0.0, theta.dtype)
    for b in range(C.N_BANDS):
        gs = ref.mog_eval(comps_s[b])
        gg = ref.mog_eval(comps_g[b])
        total = total + ref.poisson_elbo_band(
            pixels[b], bg[b], mask[b], gs, gg, scal[b]
        )
    return total


def _kl_normal(mq, vq, mp, vp):
    """KL(N(mq, vq) || N(mp, vp)); also the lognormal KL of the exps."""
    return 0.5 * (jnp.log(vp / vq) + (vq + (mq - mp) ** 2) / vp - 1.0)


def elbo_kl(theta, prior):
    """KL(q || prior) for one source, plus the ridge on location/shape.

    For the factored family the joint KL decomposes exactly:
      KL = KL_a + sum_t q(a=t) * (KL_{r|t} + KL_{c|t}).
    """
    p = unpack(theta)
    gam_g = p["gamma_gal"]
    gam_s = 1.0 - gam_g
    pg = prior[C.P_A]

    eps = jnp.asarray(1e-12, theta.dtype)
    kl_a = gam_g * jnp.log(gam_g / pg + eps) + gam_s * jnp.log(
        gam_s / (1.0 - pg) + eps
    )

    kl_r_star = _kl_normal(
        p["flux_star"][0], p["flux_star"][1],
        prior[C.P_FLUX_STAR], prior[C.P_FLUX_STAR + 1],
    )
    kl_r_gal = _kl_normal(
        p["flux_gal"][0], p["flux_gal"][1],
        prior[C.P_FLUX_GAL], prior[C.P_FLUX_GAL + 1],
    )
    kl_c_star = jnp.sum(
        _kl_normal(
            p["color_mean_star"], p["color_var_star"],
            prior[C.P_COLOR_MEAN_STAR : C.P_COLOR_MEAN_STAR + 4],
            prior[C.P_COLOR_VAR_STAR : C.P_COLOR_VAR_STAR + 4],
        )
    )
    kl_c_gal = jnp.sum(
        _kl_normal(
            p["color_mean_gal"], p["color_var_gal"],
            prior[C.P_COLOR_MEAN_GAL : C.P_COLOR_MEAN_GAL + 4],
            prior[C.P_COLOR_VAR_GAL : C.P_COLOR_VAR_GAL + 4],
        )
    )

    ridge_dims = jnp.concatenate(
        [theta[C.I_LOC : C.I_LOC + 2], theta[C.I_SHAPE : C.I_SHAPE + 4]]
    )
    ridge = 0.5 * C.RIDGE * jnp.sum(ridge_dims**2)

    # galaxy-shape prior (negative log density, constants dropped),
    # weighted by q(a = galaxy) — see constants.SHAPE_PRIOR_*.
    def nlp(x, mv):
        return 0.5 * (x - mv[0]) ** 2 / mv[1]

    shape_prior = gam_g * (
        nlp(theta[C.I_SHAPE], C.SHAPE_PRIOR_PDEV)
        + nlp(theta[C.I_SHAPE + 1], C.SHAPE_PRIOR_AXIS)
        + nlp(theta[C.I_SHAPE + 3], C.SHAPE_PRIOR_SCALE)
    )

    return (
        kl_a
        + gam_s * (kl_r_star + kl_c_star)
        + gam_g * (kl_r_gal + kl_c_gal)
        + ridge
        + shape_prior
    )


def elbo(theta, pixels, bg, mask, psf, gain, prior):
    """Full single-epoch ELBO (used in tests; Rust composes the pieces)."""
    return elbo_like(theta, pixels, bg, mask, psf, gain) - elbo_kl(theta, prior)


# ---------------------------------------------------------------------------
# AOT entry points: value + gradient + Hessian
# ---------------------------------------------------------------------------

def like_vgh(theta, pixels, bg, mask, psf, gain):
    """(value, grad, hess) of elbo_like at theta — the autodiff artifact."""
    f = elbo_like(theta, pixels, bg, mask, psf, gain)
    g = jax.grad(elbo_like)(theta, pixels, bg, mask, psf, gain)
    h = jax.hessian(elbo_like)(theta, pixels, bg, mask, psf, gain)
    return f, g, h


def kl_vgh(theta, prior):
    """(value, grad, hess) of elbo_kl at theta."""
    f = elbo_kl(theta, prior)
    g = jax.grad(elbo_kl)(theta, prior)
    h = jax.hessian(elbo_kl)(theta, prior)
    return f, g, h
